//! The worker side of the pool: one OS thread that owns live
//! [`SessionRun`]s and the thread-local PJRT engine that executes them.
//!
//! Everything that crosses the thread boundary — [`WorkerMsg`] and its
//! replies — is `Send` plain data (specs, command enums, checkpoint
//! metadata, outcome reports). The non-`Send` execution state (the
//! `xla` client, compiled executables, live model parameters, data
//! generators) is constructed *inside* the worker thread on first use
//! and never leaves it. That is what makes the session-execution path
//! safe to parallelize without making the PJRT types themselves
//! thread-safe.
//!
//! # Adoption and stealing
//!
//! Sessions are not handed to a worker directly: they queue as
//! `PendingSession`s (plain data) in the shared injector / per-worker
//! deques, and each fork-join round starts with an adoption pass. A
//! worker below the pool's fair share first drains its own deque, then
//! the injector, then steals the oldest pending session from the
//! most-loaded peer — materializing each claimed session into a
//! [`SessionRun`] on its own thread. The shared routing table is
//! updated at materialization time, so a stolen session's command
//! mailbox (pause / resume / lr-edit / rewind) re-homes to the thief
//! and control verbs keep landing on the thread that owns the run.

use super::queue::{PendingSession, Shared};
use crate::data::generator_for;
use crate::events::{EventKind, EventLog, Level};
use crate::runtime::{Engine, TrainableModel};
use crate::serving::{ServeWork, ServedModel, ServedRow};
use crate::session::{RunStatus, SessionRun, SessionSpec, SessionState, SessionStore};
use crate::storage::{Checkpoint, CheckpointStore};
use crate::util::clock::SharedClock;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

/// Everything a worker needs to build and drive sessions. All fields
/// are `Send + Sync` handles onto the platform's shared control state
/// (stores are `Arc<Mutex<..>>` inside); the engine is *not* here — each
/// worker creates its own from `artifacts_dir`.
#[derive(Clone)]
pub struct WorkerCtx {
    pub artifacts_dir: PathBuf,
    pub checkpoints: CheckpointStore,
    pub sessions: SessionStore,
    pub events: EventLog,
    pub clock: SharedClock,
}

/// A control-plane command routed to the worker that owns a session
/// (the §3.3 pause/resume/edit verbs, executed inside the pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionCommand {
    /// Checkpoint and mark paused.
    Pause,
    /// Apply an optional new learning rate; the facade flips the
    /// session record back to `Running` afterwards.
    Resume { lr: Option<f64> },
    /// Edit the learning rate mid-training.
    SetLr(f64),
    /// Rewind to an earlier checkpointed step.
    Rewind(u64),
}

/// What happened to one session during a step round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionOutcome {
    /// Stepped, more work remains.
    Progressed,
    /// Reached `total_steps`; the run has been dropped from the worker.
    Completed,
    /// Training errored (e.g. non-finite loss); run dropped.
    Failed(String),
    /// Not in `Running` state (paused/stopped externally); untouched.
    Skipped,
}

/// A snapshot of a live run's in-worker state (tests and the CLI peek
/// at the effective lr after an in-training edit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionProbe {
    pub steps_done: u64,
    pub lr: f32,
}

/// The worker mailbox vocabulary. Every request that needs an answer
/// carries its own reply channel, so the pool can fan a message out to
/// many workers and collect replies without blocking the workers on
/// each other. Id-addressed messages materialize their target first if
/// it is still pending on this worker's deque.
pub(super) enum WorkerMsg {
    /// Apply a session-control command to an owned run.
    Control { id: String, cmd: SessionCommand, reply: Sender<Result<(), String>> },
    /// Adopt pending work, then step every owned `Running` session by
    /// up to `chunk` steps.
    StepRound { chunk: u64, reply: Sender<Vec<(String, SessionOutcome)>> },
    /// Step one owned session by up to `steps` (automl trial driving).
    StepSession { id: String, steps: u64, reply: Sender<Result<SessionOutcome, String>> },
    /// Evaluate an owned run on a held-out batch; replies (loss, metric).
    Evaluate { id: String, eval_seed: u64, reply: Sender<Result<(f64, f64), String>> },
    /// Checkpoint an owned run now; replies with the checkpoint record.
    Checkpoint { id: String, reply: Sender<Result<Checkpoint, String>> },
    /// Peek at a run's current step/lr.
    Inspect { id: String, reply: Sender<Option<SessionProbe>> },
    /// Drop a run without touching its session record (stop/orphan).
    Detach { id: String, reply: Sender<()> },
    /// Execute one serving micro-batch on this worker's replica of the
    /// endpoint. Fire-and-forget: no reply channel — the worker fires
    /// each request's own reply callback and publishes `InferServed`,
    /// so the platform thread never waits on inference.
    Serve(Box<ServeWork>),
    /// Evict this worker's cached served model for a retired endpoint.
    DropServed { endpoint: String },
    /// Exit the worker loop.
    Shutdown,
}

/// The per-thread worker state: owned runs + the lazily-built engine.
struct Worker {
    index: usize,
    ctx: WorkerCtx,
    shared: Arc<Shared>,
    // The engine (PJRT client + compile cache) is created lazily so
    // idle workers cost nothing but a parked thread.
    engine: Option<Arc<Engine>>,
    runs: BTreeMap<String, SessionRun>,
    /// This worker's serving replicas: endpoint → (version, model).
    /// Rebuilt from the `Arc`-shared checkpoint bytes whenever a batch
    /// arrives for a different version; the engine's compile cache
    /// makes the rebuild a deserialization, never a recompile.
    served: BTreeMap<String, (u64, ServedModel)>,
}

/// The worker thread body: a mailbox loop over owned runs.
pub(super) fn worker_loop(index: usize, ctx: WorkerCtx, shared: Arc<Shared>, rx: Receiver<WorkerMsg>) {
    let mut w = Worker {
        index,
        ctx,
        shared,
        engine: None,
        runs: BTreeMap::new(),
        served: BTreeMap::new(),
    };
    while let Ok(msg) = rx.recv() {
        if matches!(msg, WorkerMsg::Shutdown) {
            break;
        }
        let t0 = Instant::now();
        w.handle(msg);
        w.shared.add_busy(index, t0.elapsed());
    }
}

impl Worker {
    fn handle(&mut self, msg: WorkerMsg) {
        match msg {
            WorkerMsg::Control { id, cmd, reply } => {
                let res = self.ensure_local(&id).and_then(|_| match self.runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => apply_command(run, cmd),
                });
                let _ = reply.send(res);
            }
            WorkerMsg::StepRound { chunk, reply } => {
                let _ = reply.send(self.step_round(chunk));
            }
            WorkerMsg::StepSession { id, steps, reply } => {
                let res = self.ensure_local(&id).and_then(|_| match self.runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => match run.step_chunk(steps) {
                        Ok(RunStatus::InProgress) => Ok(SessionOutcome::Progressed),
                        Ok(RunStatus::Completed) => Ok(SessionOutcome::Completed),
                        Err(e) => Err(format!("{:#}", e)),
                    },
                });
                // Completed (or failed mid-step): drop the run. A
                // "not active" error has no run, so this is a no-op.
                if !matches!(res, Ok(SessionOutcome::Progressed)) {
                    self.drop_run(&id);
                }
                let _ = reply.send(res);
            }
            WorkerMsg::Evaluate { id, eval_seed, reply } => {
                let res = self.ensure_local(&id).and_then(|_| match self.runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => evaluate_held_out(run, eval_seed),
                });
                let _ = reply.send(res);
            }
            WorkerMsg::Checkpoint { id, reply } => {
                let res = self.ensure_local(&id).and_then(|_| match self.runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => run.checkpoint().map_err(|e| format!("{:#}", e)),
                });
                let _ = reply.send(res);
            }
            WorkerMsg::Inspect { id, reply } => {
                // Read-only peek: never materializes a pending session.
                let probe = self
                    .runs
                    .get(&id)
                    .map(|run| SessionProbe { steps_done: run.steps_done(), lr: run.lr() });
                let _ = reply.send(probe);
            }
            WorkerMsg::Detach { id, reply } => {
                self.drop_run(&id);
                let _ = reply.send(());
            }
            WorkerMsg::Serve(work) => self.serve_batch(*work),
            WorkerMsg::DropServed { endpoint } => {
                self.served.remove(&endpoint);
            }
            WorkerMsg::Shutdown => unreachable!("handled by worker_loop"),
        }
    }

    /// Execute one serving micro-batch: rebuild this worker's replica
    /// if the version moved, run the fixed-shape executable, answer
    /// every request, publish the latency sample. The in-flight guard
    /// rides in `work` and drops when this returns, waking any drain.
    fn serve_batch(&mut self, work: ServeWork) {
        let ServeWork { endpoint, version, model, params, batch, guard } = work;
        let t0 = Instant::now();
        let n = batch.len();
        let rows: Vec<Vec<f32>> = batch.iter().map(|r| r.x.clone()).collect();
        let result = self
            .replica_for(&endpoint, version, &model, &params)
            .and_then(|served| served.serve_rows(&rows));
        match result {
            Ok(outs) => {
                for (req, probs) in batch.into_iter().zip(outs) {
                    (req.reply)(Ok(ServedRow { probs, version, batch: n }));
                }
                let latency_ms = t0.elapsed().as_secs_f64() * 1000.0;
                self.ctx.events.bus().publish(
                    Level::Debug,
                    "serving",
                    &endpoint,
                    EventKind::InferServed { batch: n as u64, latency_ms },
                );
            }
            Err(e) => {
                let msg = format!("serving '{}' v{}: {}", endpoint, version, e);
                self.ctx.events.error("serving", &endpoint, msg.clone());
                for req in batch {
                    (req.reply)(Err(msg.clone()));
                }
            }
        }
        drop(guard);
    }

    /// This worker's replica of `endpoint` at `version`, rebuilding
    /// from the shared checkpoint bytes on first use or version change.
    fn replica_for(
        &mut self,
        endpoint: &str,
        version: u64,
        model: &str,
        params: &[u8],
    ) -> Result<&ServedModel, String> {
        let stale = self.served.get(endpoint).map(|(v, _)| *v != version).unwrap_or(true);
        if stale {
            let engine = self.engine()?;
            let restored = TrainableModel::from_checkpoint(engine, model, params)
                .map_err(|e| format!("{:#}", e))?;
            let replica = ServedModel::new(restored)?;
            self.served.insert(endpoint.to_string(), (version, replica));
        }
        Ok(&self.served.get(endpoint).expect("replica just ensured").1)
    }

    /// One fork-join round: adopt pending work (own deque → injector →
    /// steal), then step every owned `Running` session.
    fn step_round(&mut self, chunk: u64) -> Vec<(String, SessionOutcome)> {
        let mut out = self.adopt_pending();
        let ids: Vec<String> = self.runs.keys().cloned().collect();
        for id in ids {
            // Skip sessions whose state got externally flipped
            // (paused/stopped) since the last round.
            if self.ctx.sessions.get(&id).map(|r| r.state) != Some(SessionState::Running) {
                out.push((id, SessionOutcome::Skipped));
                continue;
            }
            let run = self.runs.get_mut(&id).expect("run for listed id");
            match run.step_chunk(chunk) {
                Ok(RunStatus::InProgress) => out.push((id, SessionOutcome::Progressed)),
                Ok(RunStatus::Completed) => {
                    self.drop_run(&id);
                    out.push((id, SessionOutcome::Completed));
                }
                Err(e) => {
                    let msg = format!("{:#}", e);
                    self.drop_run(&id);
                    out.push((id, SessionOutcome::Failed(msg)));
                }
            }
        }
        out
    }

    /// Claim pending sessions until this worker holds its fair share of
    /// the pool's total work (own deque first, then the injector, then
    /// stealing from the most-loaded peer). With stealing disabled the
    /// worker simply drains everything routed to it. Returns spawn
    /// failures as `Failed` outcomes for the round report.
    fn adopt_pending(&mut self) -> Vec<(String, SessionOutcome)> {
        let mut failures = Vec::new();
        let fair = self.shared.fair_share();
        loop {
            if self.shared.stealing() && self.shared.live_count(self.index) >= fair {
                break;
            }
            let next = self
                .shared
                .pop_own(self.index)
                .or_else(|| self.shared.pop_injected(self.index))
                .or_else(|| {
                    if self.shared.stealing() {
                        self.shared.steal_for(self.index).map(|(p, victim)| {
                            self.ctx.events.bus().publish(
                                Level::Debug,
                                "executor",
                                &p.spec.id,
                                EventKind::WorkerStolen { thief: self.index, victim },
                            );
                            p
                        })
                    } else {
                        None
                    }
                });
            let Some(p) = next else { break };
            let id = p.spec.id.clone();
            if let Err(e) = self.spawn(p) {
                failures.push((id, SessionOutcome::Failed(e)));
            }
        }
        failures
    }

    /// Materialize an id-addressed session if it still sits on this
    /// worker's own pending deque (control verbs may arrive before the
    /// first step round). A failed spawn is terminal — record marked
    /// Failed, route removed — never a silently dropped session.
    fn ensure_local(&mut self, id: &str) -> Result<(), String> {
        if self.runs.contains_key(id) {
            return Ok(());
        }
        let Some(p) = self.shared.take_pending(self.index, id) else {
            return Ok(());
        };
        match self.spawn(p) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.fail_session(id, &e);
                Err(e)
            }
        }
    }

    /// Terminal bookkeeping for a session whose materialization failed
    /// on an id-addressed message. (The step-round adoption path
    /// reports a `Failed` outcome instead; the pool and platform
    /// handle the fallout there.)
    fn fail_session(&self, id: &str, err: &str) {
        self.ctx.events.error("executor", id, format!("materialization failed: {}", err));
        self.ctx.sessions.mark_failed(id, err);
        self.shared.remove_route(id);
    }

    /// Build the run (fresh start or checkpoint resume) on this thread
    /// and register ownership (route re-homed to us). The claim was
    /// already counted into this worker's live tally at pop time; a
    /// failure — or a detach that raced the materialization — releases
    /// it here.
    fn spawn(&mut self, p: PendingSession) -> Result<(), String> {
        match self.try_spawn(p) {
            Ok(true) => Ok(()),
            Ok(false) => {
                // Detached while materializing: the fresh run was
                // dropped; release the claim.
                self.shared.live_dec(self.index);
                Ok(())
            }
            Err(e) => {
                self.shared.live_dec(self.index);
                Err(e)
            }
        }
    }

    /// The thread-local engine, built on first use (training or
    /// serving — both lanes share one PJRT client + compile cache).
    fn engine(&mut self) -> Result<Arc<Engine>, String> {
        if self.engine.is_none() {
            let e = Engine::new(&self.ctx.artifacts_dir)
                .map_err(|e| format!("worker {}: engine init: {:#}", self.index, e))?;
            self.ctx.events.debug(
                "executor",
                "",
                format!("worker {} engine up ({})", self.index, e.platform_name()),
            );
            self.engine = Some(Arc::new(e));
        }
        Ok(self.engine.as_ref().expect("engine just initialized").clone())
    }

    /// Returns `Ok(false)` when a concurrent detach tombstoned the
    /// session while it was being built (the run is discarded).
    fn try_spawn(&mut self, p: PendingSession) -> Result<bool, String> {
        let engine = self.engine()?;
        let PendingSession { spec, resume } = p;
        let gen = generator_for(&spec.model, spec.seed)
            .ok_or_else(|| format!("no data generator for model {}", spec.model))?;
        let id = spec.id.clone();
        let run = build_run(&self.ctx, engine, spec, gen, resume).map_err(|e| format!("{:#}", e))?;
        if !self.shared.register_live(&id, self.index) {
            return Ok(false);
        }
        self.runs.insert(id, run);
        Ok(true)
    }

    /// Drop a live run and its load accounting (the route entry is the
    /// pool's to clean up).
    fn drop_run(&mut self, id: &str) {
        if self.runs.remove(id).is_some() {
            self.shared.live_dec(self.index);
        }
    }
}

fn build_run(
    ctx: &WorkerCtx,
    engine: Arc<Engine>,
    spec: SessionSpec,
    gen: Box<dyn crate::data::DataGen>,
    resume: bool,
) -> anyhow::Result<SessionRun> {
    if resume {
        SessionRun::resume(
            engine,
            spec,
            gen,
            ctx.checkpoints.clone(),
            ctx.sessions.clone(),
            ctx.events.clone(),
            ctx.clock.clone(),
        )
    } else {
        SessionRun::start(
            engine,
            spec,
            gen,
            ctx.checkpoints.clone(),
            ctx.sessions.clone(),
            ctx.events.clone(),
            ctx.clock.clone(),
        )
    }
}

fn apply_command(run: &mut SessionRun, cmd: SessionCommand) -> Result<(), String> {
    match cmd {
        SessionCommand::Pause => run.pause().map(|_| ()).map_err(|e| format!("{:#}", e)),
        SessionCommand::Resume { lr } => {
            if let Some(lr) = lr {
                run.set_lr(lr);
            }
            Ok(())
        }
        SessionCommand::SetLr(lr) => {
            run.set_lr(lr);
            Ok(())
        }
        SessionCommand::Rewind(step) => run.rewind_to(step).map_err(|e| format!("{:#}", e)),
    }
}

/// Score a run on a held-out batch drawn from a fixed eval seed (the
/// automl "current loss" probe; mirrors the pre-pool trial runner).
fn evaluate_held_out(run: &mut SessionRun, eval_seed: u64) -> Result<(f64, f64), String> {
    let mut gen = generator_for(&run.spec.model, eval_seed)
        .ok_or_else(|| format!("no data generator for model {}", run.spec.model))?;
    let batch = gen.eval_batch(run.model().manifest().batch);
    run.model()
        .evaluate(&batch)
        .map(|(loss, metric)| (loss as f64, metric as f64))
        .map_err(|e| format!("{:#}", e))
}
