//! The worker side of the pool: one OS thread that owns live
//! [`SessionRun`]s and the thread-local PJRT engine that executes them.
//!
//! Everything that crosses the thread boundary — [`WorkerMsg`] and its
//! replies — is `Send` plain data (specs, command enums, checkpoint
//! metadata, outcome reports). The non-`Send` execution state (the
//! `xla` client, compiled executables, live model parameters, data
//! generators) is constructed *inside* the worker thread on first use
//! and never leaves it. That is what makes the session-execution path
//! safe to parallelize without making the PJRT types themselves
//! thread-safe.

use crate::data::generator_for;
use crate::events::EventLog;
use crate::runtime::Engine;
use crate::session::{RunStatus, SessionRun, SessionSpec, SessionState, SessionStore};
use crate::storage::{Checkpoint, CheckpointStore};
use crate::util::clock::SharedClock;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

/// Everything a worker needs to build and drive sessions. All fields
/// are `Send + Sync` handles onto the platform's shared control state
/// (stores are `Arc<Mutex<..>>` inside); the engine is *not* here — each
/// worker creates its own from `artifacts_dir`.
#[derive(Clone)]
pub struct WorkerCtx {
    pub artifacts_dir: PathBuf,
    pub checkpoints: CheckpointStore,
    pub sessions: SessionStore,
    pub events: EventLog,
    pub clock: SharedClock,
}

/// A control-plane command routed to the worker that owns a session
/// (the §3.3 pause/resume/edit verbs, executed inside the pool).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SessionCommand {
    /// Checkpoint and mark paused.
    Pause,
    /// Apply an optional new learning rate; the facade flips the
    /// session record back to `Running` afterwards.
    Resume { lr: Option<f64> },
    /// Edit the learning rate mid-training.
    SetLr(f64),
    /// Rewind to an earlier checkpointed step.
    Rewind(u64),
}

/// What happened to one session during a step round.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionOutcome {
    /// Stepped, more work remains.
    Progressed,
    /// Reached `total_steps`; the run has been dropped from the worker.
    Completed,
    /// Training errored (e.g. non-finite loss); run dropped.
    Failed(String),
    /// Not in `Running` state (paused/stopped externally); untouched.
    Skipped,
}

/// A snapshot of a live run's in-worker state (tests and the CLI peek
/// at the effective lr after an in-training edit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionProbe {
    pub steps_done: u64,
    pub lr: f32,
}

/// The worker mailbox vocabulary. Every request that needs an answer
/// carries its own reply channel, so the pool can fan a message out to
/// many workers and collect replies without blocking the workers on
/// each other.
pub(super) enum WorkerMsg {
    /// Construct a run (fresh or checkpoint-resume) for `spec`.
    Spawn { spec: SessionSpec, resume: bool, reply: Sender<Result<(), String>> },
    /// Apply a session-control command to an owned run.
    Control { id: String, cmd: SessionCommand, reply: Sender<Result<(), String>> },
    /// Step every owned `Running` session by up to `chunk` steps.
    StepRound { chunk: u64, reply: Sender<Vec<(String, SessionOutcome)>> },
    /// Step one owned session by up to `steps` (automl trial driving).
    StepSession { id: String, steps: u64, reply: Sender<Result<SessionOutcome, String>> },
    /// Evaluate an owned run on a held-out batch; replies (loss, metric).
    Evaluate { id: String, eval_seed: u64, reply: Sender<Result<(f64, f64), String>> },
    /// Checkpoint an owned run now; replies with the checkpoint record.
    Checkpoint { id: String, reply: Sender<Result<Checkpoint, String>> },
    /// Peek at a run's current step/lr.
    Inspect { id: String, reply: Sender<Option<SessionProbe>> },
    /// Drop a run without touching its session record (stop/orphan).
    Detach { id: String, reply: Sender<()> },
    /// Exit the worker loop.
    Shutdown,
}

/// The worker thread body: a mailbox loop over owned runs.
pub(super) fn worker_loop(index: usize, ctx: WorkerCtx, rx: Receiver<WorkerMsg>) {
    // The engine (PJRT client + compile cache) is created lazily so
    // idle workers cost nothing but a parked thread.
    let mut engine: Option<Arc<Engine>> = None;
    let mut runs: BTreeMap<String, SessionRun> = BTreeMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Spawn { spec, resume, reply } => {
                let res = spawn_run(index, &ctx, &mut engine, &mut runs, spec, resume);
                let _ = reply.send(res);
            }
            WorkerMsg::Control { id, cmd, reply } => {
                let res = match runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => apply_command(run, cmd),
                };
                let _ = reply.send(res);
            }
            WorkerMsg::StepRound { chunk, reply } => {
                let mut out = Vec::new();
                let ids: Vec<String> = runs.keys().cloned().collect();
                for id in ids {
                    // Skip sessions whose state got externally flipped
                    // (paused/stopped) since the last round.
                    if ctx.sessions.get(&id).map(|r| r.state) != Some(SessionState::Running) {
                        out.push((id, SessionOutcome::Skipped));
                        continue;
                    }
                    let run = runs.get_mut(&id).expect("run for listed id");
                    match run.step_chunk(chunk) {
                        Ok(RunStatus::InProgress) => out.push((id, SessionOutcome::Progressed)),
                        Ok(RunStatus::Completed) => {
                            runs.remove(&id);
                            out.push((id, SessionOutcome::Completed));
                        }
                        Err(e) => {
                            runs.remove(&id);
                            out.push((id, SessionOutcome::Failed(format!("{:#}", e))));
                        }
                    }
                }
                let _ = reply.send(out);
            }
            WorkerMsg::StepSession { id, steps, reply } => {
                let res = match runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => match run.step_chunk(steps) {
                        Ok(RunStatus::InProgress) => Ok(SessionOutcome::Progressed),
                        Ok(RunStatus::Completed) => {
                            runs.remove(&id);
                            Ok(SessionOutcome::Completed)
                        }
                        Err(e) => {
                            runs.remove(&id);
                            Err(format!("{:#}", e))
                        }
                    },
                };
                let _ = reply.send(res);
            }
            WorkerMsg::Evaluate { id, eval_seed, reply } => {
                let res = match runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => evaluate_held_out(run, eval_seed),
                };
                let _ = reply.send(res);
            }
            WorkerMsg::Checkpoint { id, reply } => {
                let res = match runs.get_mut(&id) {
                    None => Err(format!("session {} is not active", id)),
                    Some(run) => run.checkpoint().map_err(|e| format!("{:#}", e)),
                };
                let _ = reply.send(res);
            }
            WorkerMsg::Inspect { id, reply } => {
                let probe = runs
                    .get(&id)
                    .map(|run| SessionProbe { steps_done: run.steps_done(), lr: run.lr() });
                let _ = reply.send(probe);
            }
            WorkerMsg::Detach { id, reply } => {
                runs.remove(&id);
                let _ = reply.send(());
            }
            WorkerMsg::Shutdown => break,
        }
    }
}

fn spawn_run(
    index: usize,
    ctx: &WorkerCtx,
    engine: &mut Option<Arc<Engine>>,
    runs: &mut BTreeMap<String, SessionRun>,
    spec: SessionSpec,
    resume: bool,
) -> Result<(), String> {
    if engine.is_none() {
        let e = Engine::new(&ctx.artifacts_dir)
            .map_err(|e| format!("worker {}: engine init: {:#}", index, e))?;
        ctx.events.debug(
            "executor",
            "",
            format!("worker {} engine up ({})", index, e.platform_name()),
        );
        *engine = Some(Arc::new(e));
    }
    let engine = engine.as_ref().expect("engine just initialized").clone();
    let gen = generator_for(&spec.model, spec.seed)
        .ok_or_else(|| format!("no data generator for model {}", spec.model))?;
    let id = spec.id.clone();
    let run = if resume {
        SessionRun::resume(
            engine,
            spec,
            gen,
            ctx.checkpoints.clone(),
            ctx.sessions.clone(),
            ctx.events.clone(),
            ctx.clock.clone(),
        )
    } else {
        SessionRun::start(
            engine,
            spec,
            gen,
            ctx.checkpoints.clone(),
            ctx.sessions.clone(),
            ctx.events.clone(),
            ctx.clock.clone(),
        )
    }
    .map_err(|e| format!("{:#}", e))?;
    runs.insert(id, run);
    Ok(())
}

fn apply_command(run: &mut SessionRun, cmd: SessionCommand) -> Result<(), String> {
    match cmd {
        SessionCommand::Pause => run.pause().map(|_| ()).map_err(|e| format!("{:#}", e)),
        SessionCommand::Resume { lr } => {
            if let Some(lr) = lr {
                run.set_lr(lr);
            }
            Ok(())
        }
        SessionCommand::SetLr(lr) => {
            run.set_lr(lr);
            Ok(())
        }
        SessionCommand::Rewind(step) => run.rewind_to(step).map_err(|e| format!("{:#}", e)),
    }
}

/// Score a run on a held-out batch drawn from a fixed eval seed (the
/// automl "current loss" probe; mirrors the pre-pool trial runner).
fn evaluate_held_out(run: &mut SessionRun, eval_seed: u64) -> Result<(f64, f64), String> {
    let mut gen = generator_for(&run.spec.model, eval_seed)
        .ok_or_else(|| format!("no data generator for model {}", run.spec.model))?;
    let batch = gen.eval_batch(run.model().manifest().batch);
    run.model()
        .evaluate(&batch)
        .map(|(loss, metric)| (loss as f64, metric as f64))
        .map_err(|e| format!("{:#}", e))
}
