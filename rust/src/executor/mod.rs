//! Parallel session execution: a fixed-size, `Send`-capable worker
//! pool with work stealing that trains many sessions concurrently (the
//! throughput layer behind the paper's "parallel runs with different
//! job priorities", §3.1, and the NSML follow-up's executor tier).
//!
//! # Architecture
//!
//! ```text
//!              NsmlPlatform (facade thread)
//!   run/pause/resume/stop/drive            automl trial runner
//!        │                                        │
//!        ▼                                        ▼
//!   ExecutorPool ──── routing table: session id → worker (mailbox)
//!        │ submit → injector / per-worker pending deques
//!        │ control/step_round/step_many          (mpsc mailboxes)
//!   ┌────┴─────┬──────────┬──────────┐
//!   ▼          ▼          ▼          ▼
//! worker 0   worker 1   worker 2   worker 3      (std::thread)
//!  Engine     Engine     Engine     Engine       (thread-local PJRT)
//!  SessionRun SessionRun SessionRun SessionRun   (owned, never Send)
//!      ▲ own deque → injector → steal oldest from most-loaded peer
//! ```
//!
//! * **Ownership inversion.** The platform never owns a live
//!   [`SessionRun`](crate::session::SessionRun): each *worker thread*
//!   owns its runs, and the pool holds only the queues and the routing
//!   table. The session-execution path crosses threads exclusively
//!   through `Send` messages ([`WorkerCtx`] handles are `Arc`-backed
//!   stores; specs, commands and outcomes are plain data), while the
//!   non-`Send` PJRT state (client, executables, parameters,
//!   generators) is built inside each worker and never leaves it.
//! * **Placement and work stealing.** A submission queues as pending
//!   data: the scheduler's node decision maps onto a worker's deque
//!   (`node % workers`, so co-located sessions share an engine compile
//!   cache — the analogue of NSML ML containers sharing a GPU host),
//!   and placement-less work lands in a shared injector. At the start
//!   of every round a worker below its fair share of the pool's load
//!   first drains its own deque, then the injector, then *steals* the
//!   oldest pending session from the most-loaded peer — so a skewed
//!   node→worker mapping no longer serializes the batch on one thread.
//!   Stealing re-homes the session's route (its command-mailbox
//!   address), so pause/resume/lr-edit keep reaching the owning thread.
//! * **Fork-join rounds.** [`ExecutorPool::step_round`] broadcasts a
//!   step budget to every worker and joins on the outcomes. Workers
//!   run concurrently; callers keep the deterministic, synchronous
//!   `drive()` contract the rest of the platform (and its tests) rely
//!   on. [`ExecutorPool::step_many`] is the per-session variant that
//!   lets automl rungs train all surviving candidates in parallel.
//! * **Per-session mailboxes.** Control verbs (pause, resume with a
//!   new lr, lr edit, rewind) are routed through the owning worker's
//!   mailbox keyed by session id and acknowledged synchronously, so a
//!   command observed as `Ok` has already happened.
//! * **Telemetry.** Each worker accumulates busy-time, live-session,
//!   queue-depth and steal counters ([`WorkerStats`]), surfaced through
//!   [`ExecutorPool::stats`] to `UtilizationMonitor`, `nsml cluster`
//!   and the web API's `GET /api/v1/executor`.
//!
//! Failure isolation: a session that errors (non-finite loss, bad
//! spec) is dropped from its worker and reported as
//! [`SessionOutcome::Failed`]; other sessions — including those on the
//! same worker — are unaffected.

mod pool;
mod queue;
mod worker;

pub use pool::ExecutorPool;
pub use queue::WorkerStats;
pub use worker::{SessionCommand, SessionOutcome, SessionProbe, WorkerCtx};
