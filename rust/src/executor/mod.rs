//! Parallel session execution: a fixed-size, `Send`-capable worker
//! pool that trains many sessions concurrently (the throughput layer
//! behind the paper's "parallel runs with different job priorities",
//! §3.1, and the NSML follow-up's executor tier).
//!
//! # Architecture
//!
//! ```text
//!              NsmlPlatform (facade thread)
//!   run/pause/resume/stop/drive            automl trial runner
//!        │                                        │
//!        ▼                                        ▼
//!   ExecutorPool ──────── routing table: session id → worker
//!        │ submit/control/step_round/step_many  (mpsc mailboxes)
//!   ┌────┴─────┬──────────┬──────────┐
//!   ▼          ▼          ▼          ▼
//! worker 0   worker 1   worker 2   worker 3      (std::thread)
//!  Engine     Engine     Engine     Engine       (thread-local PJRT)
//!  SessionRun SessionRun SessionRun SessionRun   (owned, never Send)
//! ```
//!
//! * **Ownership inversion.** Before this module the platform owned
//!   every live [`SessionRun`](crate::session::SessionRun) in a
//!   `RefCell` map and stepped them serially. Now each *worker thread*
//!   owns its runs; the platform holds only the routing table. The
//!   session-execution path crosses threads exclusively through `Send`
//!   messages ([`WorkerCtx`] handles are `Arc`-backed stores; specs,
//!   commands and outcomes are plain data), while the non-`Send` PJRT
//!   state (client, executables, parameters, generators) is built
//!   inside each worker and never leaves it.
//! * **Placement mapping.** The scheduler's node decision maps onto a
//!   worker (`node % workers`, see
//!   [`ExecutorPool::submit`]), so sessions co-located on a simulated
//!   node share one engine compile cache — the analogue of NSML ML
//!   containers sharing a GPU host.
//! * **Fork-join rounds.** [`ExecutorPool::step_round`] broadcasts a
//!   step budget to every worker and joins on the outcomes. Workers
//!   run concurrently; callers keep the deterministic, synchronous
//!   `drive()` contract the rest of the platform (and its tests) rely
//!   on. [`ExecutorPool::step_many`] is the per-session variant that
//!   lets automl rungs train all surviving candidates in parallel.
//! * **Per-session mailboxes.** Control verbs (pause, resume with a
//!   new lr, lr edit, rewind) are routed through the owning worker's
//!   mailbox keyed by session id and acknowledged synchronously, so a
//!   command observed as `Ok` has already happened.
//!
//! Failure isolation: a session that errors (non-finite loss, bad
//! spec) is dropped from its worker and reported as
//! [`SessionOutcome::Failed`]; other sessions — including those on the
//! same worker — are unaffected.

mod pool;
mod worker;

pub use pool::ExecutorPool;
pub use worker::{SessionCommand, SessionOutcome, SessionProbe, WorkerCtx};
