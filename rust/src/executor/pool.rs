//! The pool side: fixed worker set, session→worker routing, and the
//! fork-join step round that gives the platform parallel training with
//! serial-drive semantics.

use super::worker::{
    worker_loop, SessionCommand, SessionOutcome, SessionProbe, WorkerCtx, WorkerMsg,
};
use crate::cluster::NodeId;
use crate::session::SessionSpec;
use crate::storage::Checkpoint;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

struct WorkerHandle {
    tx: Sender<WorkerMsg>,
    thread: Option<JoinHandle<()>>,
}

/// A fixed-size pool of session-execution workers.
///
/// The pool owns the routing table (which worker holds which live
/// session — the per-session mailbox address) and exposes:
///
/// * [`submit`](ExecutorPool::submit) — place a session on a worker;
///   the scheduler's node choice maps deterministically onto a worker,
///   so co-located sessions share an engine cache like co-located NSML
///   containers share a GPU host.
/// * [`control`](ExecutorPool::control) — route a pause/resume/lr-edit/
///   rewind command to the owning worker and wait for the ack.
/// * [`step_round`](ExecutorPool::step_round) — broadcast "advance by
///   `chunk` steps" to every worker and join on the per-session
///   outcomes. Workers step concurrently; the caller keeps the old
///   serial `drive()` semantics (all progress is done when it returns).
/// * [`step_many`](ExecutorPool::step_many) — per-session step budgets
///   fanned out and joined (the automl rung driver).
pub struct ExecutorPool {
    workers: Vec<WorkerHandle>,
    routes: Mutex<BTreeMap<String, usize>>,
    rr: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `workers` threads (at least one) over a shared context.
    pub fn new(workers: usize, ctx: WorkerCtx) -> ExecutorPool {
        let n = workers.max(1);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel();
            let wctx = ctx.clone();
            let thread = std::thread::Builder::new()
                .name(format!("nsml-worker-{}", i))
                .spawn(move || worker_loop(i, wctx, rx))
                .expect("spawn executor worker");
            handles.push(WorkerHandle { tx, thread: Some(thread) });
        }
        ExecutorPool { workers: handles, routes: Mutex::new(BTreeMap::new()), rr: AtomicUsize::new(0) }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Ids of all live (pool-owned) sessions.
    pub fn active(&self) -> Vec<String> {
        self.routes.lock().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.routes.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Which worker owns a session (None if not live in the pool).
    pub fn owner_of(&self, id: &str) -> Option<usize> {
        self.routes.lock().unwrap().get(id).copied()
    }

    /// Place a session on a worker and construct its run (fresh start
    /// or checkpoint resume). `placement` is the scheduler's node
    /// decision: node → worker is a stable modular mapping; without a
    /// placement the pool round-robins.
    pub fn submit(&self, spec: SessionSpec, resume: bool, placement: Option<NodeId>) -> Result<()> {
        let w = match placement {
            Some(node) => node.0 as usize % self.workers.len(),
            None => self.rr.fetch_add(1, Ordering::Relaxed) % self.workers.len(),
        };
        let id = spec.id.clone();
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Spawn { spec, resume, reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during spawn", w))?
            .map_err(|e| anyhow!(e))?;
        self.routes.lock().unwrap().insert(id, w);
        Ok(())
    }

    /// Route a session-control command to the owning worker's mailbox
    /// and block for its ack.
    pub fn control(&self, id: &str, cmd: SessionCommand) -> Result<()> {
        let w = self.owner_of(id).ok_or_else(|| anyhow!("session {} is not active", id))?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Control { id: id.to_string(), cmd, reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during {:?}", w, cmd))?
            .map_err(|e| anyhow!(e))
    }

    /// Drop a session's run without touching its record (stop/orphan).
    /// Synchronous, so a re-submit (checkpoint recovery) can never race
    /// the old run. A session the pool does not own is a no-op.
    pub fn detach(&self, id: &str) {
        let w = match self.routes.lock().unwrap().remove(id) {
            Some(w) => w,
            None => return,
        };
        let (reply, rx) = channel();
        if self.workers[w].tx.send(WorkerMsg::Detach { id: id.to_string(), reply }).is_ok() {
            let _ = rx.recv();
        }
    }

    /// Advance every live `Running` session by up to `chunk` steps.
    /// Workers step their sessions concurrently; this returns once all
    /// workers report, with one outcome per owned session. Sessions
    /// that completed or failed are already dropped from the pool.
    pub fn step_round(&self, chunk: u64) -> Vec<(String, SessionOutcome)> {
        let mut pending = Vec::with_capacity(self.workers.len());
        for w in &self.workers {
            let (reply, rx) = channel();
            if w.tx.send(WorkerMsg::StepRound { chunk, reply }).is_ok() {
                pending.push(rx);
            }
        }
        let mut out = Vec::new();
        for rx in pending {
            if let Ok(mut v) = rx.recv() {
                out.append(&mut v);
            }
        }
        let mut routes = self.routes.lock().unwrap();
        for (id, oc) in &out {
            if matches!(oc, SessionOutcome::Completed | SessionOutcome::Failed(_)) {
                routes.remove(id);
            }
        }
        out
    }

    /// Step a specific set of sessions, each by its own budget, in
    /// parallel across their owning workers. Returns one result per
    /// input id, in input order.
    pub fn step_many(&self, work: &[(String, u64)]) -> Vec<(String, Result<SessionOutcome, String>)> {
        let mut pending = Vec::with_capacity(work.len());
        for (id, steps) in work {
            let Some(w) = self.owner_of(id) else {
                pending.push((id.clone(), Err(format!("session {} is not active", id))));
                continue;
            };
            let (reply, rx) = channel();
            match self.workers[w].tx.send(WorkerMsg::StepSession {
                id: id.clone(),
                steps: *steps,
                reply,
            }) {
                Ok(()) => pending.push((id.clone(), Ok(rx))),
                Err(_) => pending.push((id.clone(), Err(format!("executor worker {} is gone", w)))),
            }
        }
        let mut out = Vec::with_capacity(pending.len());
        for (id, slot) in pending {
            let res = match slot {
                Ok(rx) => match rx.recv() {
                    Ok(r) => r,
                    Err(_) => Err("executor worker died mid-step".to_string()),
                },
                Err(e) => Err(e),
            };
            if !matches!(res, Ok(SessionOutcome::Progressed) | Ok(SessionOutcome::Skipped)) {
                // Completed or failed: the worker dropped the run.
                self.routes.lock().unwrap().remove(&id);
            }
            out.push((id, res));
        }
        out
    }

    /// Held-out evaluation of a live session: (loss, metric).
    pub fn evaluate(&self, id: &str, eval_seed: u64) -> Result<(f64, f64)> {
        let w = self.owner_of(id).ok_or_else(|| anyhow!("session {} is not active", id))?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Evaluate { id: id.to_string(), eval_seed, reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during evaluate", w))?
            .map_err(|e| anyhow!(e))
    }

    /// Checkpoint a live session now; returns the checkpoint record.
    pub fn checkpoint(&self, id: &str) -> Result<Checkpoint> {
        let w = self.owner_of(id).ok_or_else(|| anyhow!("session {} is not active", id))?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Checkpoint { id: id.to_string(), reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during checkpoint", w))?
            .map_err(|e| anyhow!(e))
    }

    /// Peek at a live run's current step/lr (None if not pool-owned).
    pub fn inspect(&self, id: &str) -> Option<SessionProbe> {
        let w = self.owner_of(id)?;
        let (reply, rx) = channel();
        self.workers[w].tx.send(WorkerMsg::Inspect { id: id.to_string(), reply }).ok()?;
        rx.recv().ok()?
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}
