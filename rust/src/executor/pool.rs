//! The pool side: fixed worker set, the work-distribution queues
//! (injector + per-worker deques), session routing, and the fork-join
//! step round that gives the platform parallel training with
//! serial-drive semantics.

use super::queue::{PendingSession, Route, Shared, WorkerStats};
use super::worker::{
    worker_loop, SessionCommand, SessionOutcome, SessionProbe, WorkerCtx, WorkerMsg,
};
use crate::cluster::NodeId;
use crate::data::generator_for;
use crate::serving::ServeWork;
use crate::session::SessionSpec;
use crate::storage::Checkpoint;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

struct WorkerHandle {
    tx: Sender<WorkerMsg>,
    thread: Option<JoinHandle<()>>,
}

/// A fixed-size pool of session-execution workers with work stealing.
///
/// Submissions do not land on a worker directly: they queue as pending
/// sessions — on the preferred worker's deque when the scheduler chose
/// a node (`node % workers`), or in the shared injector when it did
/// not. Workers materialize pending sessions at the start of each
/// fork-join round, and a worker below its fair share steals the oldest
/// pending session from the most-loaded peer, so a skewed node→worker
/// mapping no longer leaves workers idle. Stealing re-homes the
/// session's route, which is also its command-mailbox address.
///
/// The pool exposes:
///
/// * [`submit`](ExecutorPool::submit) — queue a session (validated
///   eagerly; materialized by whichever worker claims it).
/// * [`control`](ExecutorPool::control) — route a pause/resume/lr-edit/
///   rewind command to the owning worker and wait for the ack.
/// * [`step_round`](ExecutorPool::step_round) — broadcast "adopt
///   pending work, then advance by `chunk` steps" to every worker and
///   join on the per-session outcomes. Workers step concurrently; the
///   caller keeps the old serial `drive()` semantics (all progress is
///   done when it returns).
/// * [`step_many`](ExecutorPool::step_many) — per-session step budgets
///   fanned out and joined (the automl rung driver).
/// * [`stats`](ExecutorPool::stats) — per-worker busy-time, live
///   sessions, queue depth and steal counts for the ops surfaces
///   (`nsml cluster`, `GET /api/v1/executor`).
pub struct ExecutorPool {
    workers: Vec<WorkerHandle>,
    shared: Arc<Shared>,
    rr: AtomicUsize,
}

impl ExecutorPool {
    /// Spawn `workers` threads (at least one) over a shared context,
    /// with work stealing enabled.
    pub fn new(workers: usize, ctx: WorkerCtx) -> ExecutorPool {
        ExecutorPool::with_stealing(workers, ctx, true)
    }

    /// Like [`new`](ExecutorPool::new) but with work stealing switched
    /// off: sessions stay pinned to their `node % workers` target (the
    /// pre-steal executor behaviour, kept as the bench baseline).
    pub fn with_stealing(workers: usize, ctx: WorkerCtx, stealing: bool) -> ExecutorPool {
        let n = workers.max(1);
        let shared = Arc::new(Shared::new(n, stealing));
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel();
            let wctx = ctx.clone();
            let wshared = shared.clone();
            let thread = std::thread::Builder::new()
                .name(format!("nsml-worker-{}", i))
                .spawn(move || worker_loop(i, wctx, wshared, rx))
                .expect("spawn executor worker");
            handles.push(WorkerHandle { tx, thread: Some(thread) });
        }
        ExecutorPool { workers: handles, shared, rr: AtomicUsize::new(0) }
    }

    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Is work stealing enabled on this pool?
    pub fn stealing(&self) -> bool {
        self.shared.stealing()
    }

    /// Ids of all live or pending (pool-owned) sessions.
    pub fn active(&self) -> Vec<String> {
        self.shared.routed_ids()
    }

    pub fn len(&self) -> usize {
        self.shared.route_count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Which worker owns a session — its live run or its pending-deque
    /// slot (`None` if unknown or still in the injector).
    pub fn owner_of(&self, id: &str) -> Option<usize> {
        self.shared.route_of(id).and_then(|r| r.worker())
    }

    /// Per-worker telemetry: live sessions, pending queue depth, steal
    /// count and cumulative busy time, indexed by worker.
    pub fn stats(&self) -> Vec<WorkerStats> {
        self.shared.stats()
    }

    /// Total sessions stolen across all workers since pool start.
    pub fn total_steals(&self) -> u64 {
        self.stats().iter().map(|s| s.steals).sum()
    }

    /// Queue a session for execution (fresh start or checkpoint
    /// resume). `placement` is the scheduler's node decision: node →
    /// worker is a stable modular mapping onto that worker's pending
    /// deque; without a placement the session lands in the shared
    /// injector (or round-robins when stealing is off). The spec is
    /// validated here so unknown models fail fast; materialization
    /// happens on whichever worker claims the session.
    pub fn submit(&self, spec: SessionSpec, resume: bool, placement: Option<NodeId>) -> Result<()> {
        if generator_for(&spec.model, spec.seed).is_none() {
            return Err(anyhow!("no data generator for model {}", spec.model));
        }
        let pending = PendingSession { spec, resume };
        match placement {
            Some(node) => {
                self.shared.push_pending(node.0 as usize % self.workers.len(), pending);
            }
            None if self.shared.stealing() => self.shared.inject(pending),
            None => {
                let w = self.rr.fetch_add(1, Ordering::Relaxed) % self.workers.len();
                self.shared.push_pending(w, pending);
            }
        }
        Ok(())
    }

    /// Resolve the worker an id-addressed message should go to,
    /// assigning injected sessions to the least-loaded worker first.
    fn mailbox_of(&self, id: &str) -> Result<usize> {
        match self.shared.route_of(id) {
            None => Err(anyhow!("session {} is not active", id)),
            Some(Route::Injected) => self
                .shared
                .adopt_injected(id)
                .ok_or_else(|| anyhow!("session {} is not active", id)),
            Some(r) => r.worker().ok_or_else(|| anyhow!("session {} is not active", id)),
        }
    }

    /// Prune routes for sessions a worker dropped (completed/failed).
    fn prune_route(&self, id: &str) {
        self.shared.remove_route(id);
    }

    /// Route a session-control command to the owning worker's mailbox
    /// and block for its ack.
    pub fn control(&self, id: &str, cmd: SessionCommand) -> Result<()> {
        let w = self.mailbox_of(id)?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Control { id: id.to_string(), cmd, reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during {:?}", w, cmd))?
            .map_err(|e| anyhow!(e))
    }

    /// Drop a session's run without touching its record (stop/orphan).
    /// Synchronous, so a re-submit (checkpoint recovery) can never race
    /// the old run: a still-queued session is purged in place, a live
    /// one is dropped through its worker's mailbox, and one caught
    /// mid-steal is tombstoned so the thief discards it on arrival. A
    /// session the pool does not own is a no-op.
    pub fn detach(&self, id: &str) {
        if let Some(w) = self.shared.detach(id) {
            self.send_detach(w, id);
        }
    }

    fn send_detach(&self, w: usize, id: &str) {
        let (reply, rx) = channel();
        if self.workers[w].tx.send(WorkerMsg::Detach { id: id.to_string(), reply }).is_ok() {
            let _ = rx.recv();
        }
    }

    /// Advance every live `Running` session by up to `chunk` steps.
    /// Each worker first adopts its share of pending work (draining its
    /// deque, the injector, then stealing from loaded peers), then
    /// steps its sessions; this returns once all workers report, with
    /// one outcome per owned session. Sessions that completed or failed
    /// are already dropped from the pool.
    pub fn step_round(&self, chunk: u64) -> Vec<(String, SessionOutcome)> {
        let mut pending = Vec::with_capacity(self.workers.len());
        for w in &self.workers {
            let (reply, rx) = channel();
            if w.tx.send(WorkerMsg::StepRound { chunk, reply }).is_ok() {
                pending.push(rx);
            }
        }
        let mut out = Vec::new();
        for rx in pending {
            if let Ok(mut v) = rx.recv() {
                out.append(&mut v);
            }
        }
        for (id, oc) in &out {
            if matches!(oc, SessionOutcome::Completed | SessionOutcome::Failed(_)) {
                self.prune_route(id);
            }
        }
        out
    }

    /// Step a specific set of sessions, each by its own budget, in
    /// parallel across their owning workers. Returns one result per
    /// input id, in input order.
    pub fn step_many(&self, work: &[(String, u64)]) -> Vec<(String, Result<SessionOutcome, String>)> {
        let mut pending = Vec::with_capacity(work.len());
        for (id, steps) in work {
            let Ok(w) = self.mailbox_of(id) else {
                pending.push((id.clone(), Err(format!("session {} is not active", id))));
                continue;
            };
            let (reply, rx) = channel();
            match self.workers[w].tx.send(WorkerMsg::StepSession {
                id: id.clone(),
                steps: *steps,
                reply,
            }) {
                Ok(()) => pending.push((id.clone(), Ok(rx))),
                Err(_) => pending.push((id.clone(), Err(format!("executor worker {} is gone", w)))),
            }
        }
        let mut out = Vec::with_capacity(pending.len());
        for (id, slot) in pending {
            let res = match slot {
                Ok(rx) => match rx.recv() {
                    Ok(r) => r,
                    Err(_) => Err("executor worker died mid-step".to_string()),
                },
                Err(e) => Err(e),
            };
            if !matches!(res, Ok(SessionOutcome::Progressed) | Ok(SessionOutcome::Skipped)) {
                // Completed or failed: the worker dropped the run.
                self.prune_route(&id);
            }
            out.push((id, res));
        }
        out
    }

    /// Held-out evaluation of a live session: (loss, metric).
    pub fn evaluate(&self, id: &str, eval_seed: u64) -> Result<(f64, f64)> {
        let w = self.mailbox_of(id)?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Evaluate { id: id.to_string(), eval_seed, reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during evaluate", w))?
            .map_err(|e| anyhow!(e))
    }

    /// Checkpoint a live session now; returns the checkpoint record.
    pub fn checkpoint(&self, id: &str) -> Result<Checkpoint> {
        let w = self.mailbox_of(id)?;
        let (reply, rx) = channel();
        self.workers[w]
            .tx
            .send(WorkerMsg::Checkpoint { id: id.to_string(), reply })
            .map_err(|_| anyhow!("executor worker {} is gone", w))?;
        rx.recv()
            .map_err(|_| anyhow!("executor worker {} died during checkpoint", w))?
            .map_err(|e| anyhow!(e))
    }

    /// Peek at a live run's current step/lr (None if not pool-owned).
    pub fn inspect(&self, id: &str) -> Option<SessionProbe> {
        let w = self.mailbox_of(id).ok()?;
        let (reply, rx) = channel();
        self.workers[w].tx.send(WorkerMsg::Inspect { id: id.to_string(), reply }).ok()?;
        rx.recv().ok()?
    }

    /// Hand one serving micro-batch to `worker`'s mailbox — the serve
    /// lane. Fire-and-forget: the worker executes it and fires each
    /// request's reply callback itself, so the caller (the drive loop)
    /// overlaps inference with training instead of blocking on it.
    /// Returns the work on a dead or unknown worker so the caller can
    /// fail the batch inline.
    pub fn serve_batch_on(&self, worker: usize, work: ServeWork) -> Result<(), ServeWork> {
        let Some(handle) = self.workers.get(worker) else { return Err(work) };
        handle.tx.send(WorkerMsg::Serve(Box::new(work))).map_err(|e| match e.0 {
            WorkerMsg::Serve(w) => *w,
            _ => unreachable!("serve sends only Serve messages"),
        })
    }

    /// Evict every worker's cached served model for `endpoint`
    /// (retire). Mailbox ordering guarantees any batch sent earlier
    /// executes before the eviction lands.
    pub fn drop_served(&self, endpoint: &str) {
        for handle in &self.workers {
            let _ = handle.tx.send(WorkerMsg::DropServed { endpoint: endpoint.to_string() });
        }
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        for w in &self.workers {
            let _ = w.tx.send(WorkerMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}
