//! # NSML — NAVER Smart Machine Learning (reproduction)
//!
//! A full reimplementation of the NSML machine-learning platform
//! (Sung et al., 2017) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the platform itself: a simulated GPU
//!   cluster, a master–slave scheduler with leader election, a
//!   containerized execution substrate with image reuse and shared
//!   dataset mounts, a content-addressed object store, training-session
//!   management with pause/resume and in-training hyperparameter edits,
//!   a parallel session-execution worker pool ([`executor`]), a
//!   per-dataset leaderboard, AutoML search, a CLI, and a web UI.
//! * **Layer 2** — the four alpha-test models (MNIST MLP, emotion CNN,
//!   movie-rating RNN, face GAN) written in JAX and AOT-lowered to HLO
//!   text at build time (`python/compile/`).
//! * **Layer 1** — Pallas kernels (fused linear, conv2d, softmax-xent)
//!   called by the L2 models and validated against pure-jnp oracles.
//!
//! Python never runs at platform runtime: [`runtime`] loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate) and
//! executes them from the session hot path.
//!
//! # Module map
//!
//! Requests flow top-down; each layer only calls the one below it:
//!
//! * [`cli`] / [`web`] — user surfaces; both speak the versioned wire
//!   vocabulary ([`api::ApiRequest`] / [`api::ApiResponse`]).
//! * [`api`] — the three-layer API: wire format, the
//!   [`api::PlatformService::dispatch`] command/query entry point, and
//!   the [`api::NsmlPlatform`] facade that composes every subsystem.
//! * [`executor`] — the work-stealing session-execution worker pool;
//!   each `std::thread` worker owns its live runs and a thread-local
//!   PJRT engine.
//! * [`tenancy`] — multi-tenant fair share: per-user quotas
//!   ([`tenancy::TenantRegistry`]), a weighted stride admission queue
//!   in front of the scheduler, event-bus-derived GPU-second
//!   accounting, and preemption of over-quota users when others wait.
//! * [`scheduler`] / [`cluster`] / [`container`] — placement policies
//!   with leader election over a simulated GPU cluster (heartbeats,
//!   failure injection, utilization monitoring) and the containerized
//!   execution substrate.
//! * [`session`] / [`runtime`] / [`data`] — training state machines
//!   over the PJRT engine and the procedural dataset generators.
//! * [`serving`] — high-QPS inference: named endpoints promoted from
//!   the leaderboard (versioned, roll-forward/back), a per-endpoint
//!   queue that micro-batches concurrent requests into single
//!   fixed-shape engine executions, and autoscaled replica sets that
//!   run those batches on executor-pool workers instead of the
//!   platform thread.
//! * [`events`] — the typed publish/subscribe event spine: every
//!   subsystem publishes structured events (placements, state
//!   transitions, metrics, checkpoints, steals, samples) into a
//!   bounded sequence-numbered bus; the leaderboard and utilization
//!   monitor are derived consumers, and `nsml logs -f` /
//!   `GET /api/v1/events` stream it incrementally.
//! * [`durability`] — event-sourced crash safety: a WAL fed by a bus
//!   subscription, periodic compacted snapshots with WAL rotation,
//!   startup snapshot+replay recovery, and object-store GC with
//!   per-tenant storage accounting.
//! * [`obs`] — observability: a metrics registry (counters, gauges,
//!   log-bucket histograms with windowed p50/p95/p99) populated by a
//!   derived bus consumer each drive round plus direct instrumentation
//!   on dispatch/HTTP/WAL paths, request-scoped traces minted at
//!   ingress and assembled per trace id, and Prometheus text
//!   exposition at `GET /metrics`.
//! * [`storage`] / [`leaderboard`] / [`automl`] / [`util`] — object
//!   store + checkpoints, per-dataset ranking, hyperparameter search,
//!   and dependency-free utilities (JSON, TOML, argparse, tables,
//!   plots, bench harness).
//!
//! # Quickstart
//!
//! ```bash
//! bash scripts/verify.sh              # build + test + lint gate
//! cargo run --example quickstart      # submit, train, rank a session
//! cargo run -- run main.py -d mnist   # the same through the CLI
//! ```
//!
//! Start with [`api::NsmlPlatform`] or the `nsml` binary. The repo's
//! `README.md` has the CLI tour; `docs/ARCHITECTURE.md` walks a `run`
//! dispatch and a fork-join step round (including the work-steal path)
//! through every layer; `docs/BENCHMARKS.md` documents the perf gates.

pub mod util;
pub mod events;
pub mod cluster;
pub mod scheduler;
pub mod container;
pub mod storage;
pub mod runtime;
pub mod data;
pub mod session;
pub mod executor;
pub mod serving;
pub mod tenancy;
pub mod durability;
pub mod obs;
pub mod leaderboard;
pub mod automl;
pub mod api;
pub mod web;
pub mod cli;
