//! # NSML — NAVER Smart Machine Learning (reproduction)
//!
//! A full reimplementation of the NSML machine-learning platform
//! (Sung et al., 2017) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the platform itself: a simulated GPU
//!   cluster, a master–slave scheduler with leader election, a
//!   containerized execution substrate with image reuse and shared
//!   dataset mounts, a content-addressed object store, training-session
//!   management with pause/resume and in-training hyperparameter edits,
//!   a parallel session-execution worker pool ([`executor`]), a
//!   per-dataset leaderboard, AutoML search, a CLI, and a web UI.
//! * **Layer 2** — the four alpha-test models (MNIST MLP, emotion CNN,
//!   movie-rating RNN, face GAN) written in JAX and AOT-lowered to HLO
//!   text at build time (`python/compile/`).
//! * **Layer 1** — Pallas kernels (fused linear, conv2d, softmax-xent)
//!   called by the L2 models and validated against pure-jnp oracles.
//!
//! Python never runs at platform runtime: [`runtime`] loads
//! `artifacts/*.hlo.txt` through the PJRT CPU client (`xla` crate) and
//! executes them from the session hot path.
//!
//! Start with [`api::NsmlPlatform`] or the `nsml` binary.

pub mod util;
pub mod events;
pub mod cluster;
pub mod scheduler;
pub mod container;
pub mod storage;
pub mod runtime;
pub mod data;
pub mod session;
pub mod executor;
pub mod leaderboard;
pub mod automl;
pub mod api;
pub mod web;
pub mod cli;
