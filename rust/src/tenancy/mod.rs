//! Multi-tenant fair share: quotas, usage accounting and preemptive
//! admission control.
//!
//! NSML exists because many researchers share one GPU pool (the
//! paper's requirements come from a 25k-member study group), yet a
//! bare scheduler treats every submission as a single anonymous
//! stream — one user can flood the queue and starve everyone else.
//! This subsystem makes users first-class:
//!
//! * [`TenantRegistry`] — per-user [`TenantQuota`]s (max concurrent
//!   sessions, max GPUs, GPU-second budget, stride weight and
//!   [`PriorityClass`], defaults from `[tenancy]` config) plus the
//!   charge table of resources each user currently holds.
//! * [`AdmissionQueue`] — a weighted **stride** scheduler over
//!   per-user FIFO lanes that sits *in front of* the scheduler's
//!   [`JobQueue`](crate::scheduler::JobQueue) and decides which
//!   pending submission is offered to the
//!   [`Master`](crate::scheduler::Master) next
//!   (via [`Master::can_place`](crate::scheduler::Master::can_place),
//!   so capacity-blocked heads wait here, not in the scheduler).
//! * [`UsageAccountant`] — per-user GPU-seconds, derived purely from
//!   the event bus (`StateChanged` intervals ×  GPUs), never called
//!   from training hot paths.
//!
//! **Preemption** closes the loop: when a user exceeds quota while
//! another user waits for admission, the platform checkpoints and
//! pauses the over-quota user's youngest running session, frees its
//! GPUs, and parks it at the *front* of the owner's admission lane;
//! it auto-resumes from the checkpoint once the contention clears
//! (reusing the executor's pause/checkpoint machinery — see
//! `api::NsmlPlatform::drive`).
//!
//! Decisions publish as [`EventKind::AdmissionDecided`](crate::events::EventKind)
//! (`admit` / `readmit` / `defer` / `preempt`); surfaces are the
//! `tenant_report` / `set_quota` wire verbs, `GET /api/v1/tenants`,
//! and the `nsml tenants` / `nsml quota` CLI commands.
//! `benches/bench_tenancy.rs` gates two-user fairness (within 20%)
//! and admission overhead (≤5% wall-clock vs. a no-tenancy drive).

pub mod accounting;
pub mod admission;
pub mod registry;

pub use accounting::UsageAccountant;
pub use admission::{AdmissionQueue, AdmitPop, PendingAdmission, STRIDE_SCALE};
pub use registry::{PriorityClass, TenantQuota, TenantRegistry, TenantSpec};

/// The composed tenancy layer the platform facade owns: one registry,
/// one admission queue, one accountant, all internally thread-safe.
pub struct Tenancy {
    pub registry: TenantRegistry,
    pub admission: AdmissionQueue,
    pub accountant: UsageAccountant,
}

impl Tenancy {
    /// Assemble from the `[tenancy]` config: `default_quota` applies
    /// to every user, `users` seeds per-user weight/class overrides.
    pub fn new(default_quota: TenantQuota, users: &[TenantSpec]) -> Tenancy {
        let registry = TenantRegistry::new(default_quota);
        for spec in users {
            registry.update_quota(&spec.user, |q| {
                q.weight = spec.weight.max(1);
                q.class = spec.class;
            });
        }
        Tenancy {
            registry,
            admission: AdmissionQueue::new(),
            accountant: UsageAccountant::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_users_seed_weight_and_class() {
        let specs = vec![
            TenantSpec { user: "alice".into(), weight: 4, class: PriorityClass::High },
            TenantSpec { user: "bob".into(), weight: 0, class: PriorityClass::Low },
        ];
        let t = Tenancy::new(TenantQuota { max_gpus: 8, ..TenantQuota::default() }, &specs);
        let alice = t.registry.quota_of("alice");
        assert_eq!(alice.weight, 4);
        assert_eq!(alice.class, PriorityClass::High);
        assert_eq!(alice.max_gpus, 8, "overrides start from the default quota");
        // A zero weight from config is clamped to 1.
        assert_eq!(t.registry.quota_of("bob").weight, 1);
        assert_eq!(t.registry.quota_of("carol").weight, 1);
    }
}
