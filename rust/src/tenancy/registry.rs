//! Per-user quotas and resource occupancy — who may hold how much.
//!
//! The registry is the tenancy layer's source of truth for three
//! things: each user's [`TenantQuota`] (explicit override or the
//! `[tenancy]` config default), the set of users the platform has ever
//! seen (so reports cover idle tenants too), and a *charge table* of
//! cluster resources currently held per session. Charges are taken
//! when a submission is admitted and credited back exactly once when
//! the session releases its allocation (completion, stop, failure or
//! preemption) — both operations are idempotent, so retrying a release
//! on an already-credited session is a no-op, never a double credit.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Mutex;

/// Coarse admission tier across users. Higher classes are always
/// offered to the scheduler before lower ones; stride weights only
/// order users *within* a class.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum PriorityClass {
    Low = 0,
    Normal = 1,
    High = 2,
}

impl PriorityClass {
    pub fn as_str(&self) -> &'static str {
        match self {
            PriorityClass::Low => "low",
            PriorityClass::Normal => "normal",
            PriorityClass::High => "high",
        }
    }

    /// Inverse of [`PriorityClass::as_str`] (config + wire parsing).
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Option<PriorityClass> {
        match s {
            "low" => Some(PriorityClass::Low),
            "normal" => Some(PriorityClass::Normal),
            "high" => Some(PriorityClass::High),
            _ => None,
        }
    }
}

/// One user's fair-share contract. Limits use `0` (or `0.0`) to mean
/// *unlimited*, so the all-zero default admits everything — tenancy
/// only bites where an operator opted a user in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TenantQuota {
    /// Max sessions holding (or queued for) cluster resources at once.
    pub max_concurrent: usize,
    /// Max GPUs held across all of the user's sessions at once.
    pub max_gpus: usize,
    /// Lifetime GPU-second budget (virtual time); once exceeded the
    /// user only runs when no quota-clear user is waiting (the gate is
    /// work-conserving — capacity nobody else may claim is still
    /// handed out), and their youngest session is preempted when an
    /// admissible user is left waiting.
    pub gpu_second_budget: f64,
    /// Stride-scheduling weight: a weight-2 user is offered twice as
    /// many admissions as a weight-1 user under contention.
    pub weight: u32,
    /// Admission tier (see [`PriorityClass`]).
    pub class: PriorityClass,
    /// Max serving requests per sliding second of virtual time
    /// (`serve_infer`); 0 means unlimited. Enforced at enqueue, so a
    /// throttled request never reaches the micro-batcher.
    pub max_qps: u32,
}

impl Default for TenantQuota {
    fn default() -> TenantQuota {
        TenantQuota {
            max_concurrent: 0,
            max_gpus: 0,
            gpu_second_budget: 0.0,
            weight: 1,
            class: PriorityClass::Normal,
            max_qps: 0,
        }
    }
}

/// A `[tenancy] users = "name:weight:class,…"` config entry.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    pub user: String,
    pub weight: u32,
    pub class: PriorityClass,
}

struct Inner {
    default_quota: TenantQuota,
    /// Explicit per-user overrides; absent users get the default.
    quotas: BTreeMap<String, TenantQuota>,
    /// Sessions currently charged: session -> (user, gpus).
    charged: BTreeMap<String, (String, usize)>,
    /// Every user that ever submitted or was configured.
    seen: BTreeSet<String>,
    /// Object-store bytes attributed per user, refreshed by each GC
    /// mark pass (checkpoint params + records of the user's sessions).
    storage_bytes: BTreeMap<String, u64>,
    /// Serving-request timestamps (virtual ms) inside the sliding QPS
    /// window, per user. Pruned on every [`TenantRegistry::try_request`].
    requests: BTreeMap<String, Vec<u64>>,
}

/// Width of the QPS sliding window: one virtual second.
const QPS_WINDOW_MS: u64 = 1000;

/// Thread-safe quota + occupancy store (see module docs).
pub struct TenantRegistry {
    inner: Mutex<Inner>,
}

impl TenantRegistry {
    pub fn new(default_quota: TenantQuota) -> TenantRegistry {
        TenantRegistry {
            inner: Mutex::new(Inner {
                default_quota,
                quotas: BTreeMap::new(),
                charged: BTreeMap::new(),
                seen: BTreeSet::new(),
                storage_bytes: BTreeMap::new(),
                requests: BTreeMap::new(),
            }),
        }
    }

    /// The quota in force for `user` (explicit override or default).
    pub fn quota_of(&self, user: &str) -> TenantQuota {
        let inner = self.inner.lock().unwrap();
        inner.quotas.get(user).copied().unwrap_or(inner.default_quota)
    }

    /// Replace `user`'s quota outright.
    pub fn set_quota(&self, user: &str, quota: TenantQuota) {
        let mut inner = self.inner.lock().unwrap();
        inner.seen.insert(user.to_string());
        inner.quotas.insert(user.to_string(), quota);
    }

    /// Edit `user`'s quota in place, materializing it from the default
    /// first if the user had no explicit override yet.
    pub fn update_quota<F: FnOnce(&mut TenantQuota)>(&self, user: &str, f: F) {
        let mut inner = self.inner.lock().unwrap();
        inner.seen.insert(user.to_string());
        let dflt = inner.default_quota;
        let q = inner.quotas.entry(user.to_string()).or_insert(dflt);
        f(q);
    }

    /// Record that `user` exists (first submission), so reports list
    /// them even before any quota override or admission.
    pub fn note_user(&self, user: &str) {
        self.inner.lock().unwrap().seen.insert(user.to_string());
    }

    /// Every known user (submitted at least once or explicitly quota'd).
    pub fn users(&self) -> Vec<String> {
        self.inner.lock().unwrap().seen.iter().cloned().collect()
    }

    /// Explicit quota overrides (for persistence).
    pub fn overrides(&self) -> Vec<(String, TenantQuota)> {
        self.inner.lock().unwrap().quotas.iter().map(|(u, q)| (u.clone(), *q)).collect()
    }

    /// Charge an admitted session against its user. Idempotent.
    pub fn charge(&self, session: &str, user: &str, gpus: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.seen.insert(user.to_string());
        inner.charged.entry(session.to_string()).or_insert_with(|| (user.to_string(), gpus));
    }

    /// Credit a session's charge back (terminal state or preemption).
    /// Idempotent: returns the released `(user, gpus)` only the first
    /// time.
    pub fn release(&self, session: &str) -> Option<(String, usize)> {
        self.inner.lock().unwrap().charged.remove(session)
    }

    /// Overwrite `user`'s attributed object-store bytes (idempotent —
    /// each GC mark pass recomputes the absolute figure, so storage
    /// joins GPU-seconds in the per-tenant accounting).
    pub fn set_storage_bytes(&self, user: &str, bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.seen.insert(user.to_string());
        inner.storage_bytes.insert(user.to_string(), bytes);
    }

    /// Object-store bytes attributed to `user` by the last GC pass.
    pub fn storage_bytes_of(&self, user: &str) -> u64 {
        self.inner.lock().unwrap().storage_bytes.get(user).copied().unwrap_or(0)
    }

    /// Admit or throttle one serving request from `user` at `now_ms`
    /// (virtual time). Under the user's `max_qps` (or with no limit)
    /// the request is counted and admitted; at the limit it is
    /// rejected with `Err(max_qps)` and *not* counted, so a throttled
    /// client retrying does not extend its own penalty.
    pub fn try_request(&self, user: &str, now_ms: u64) -> Result<(), u32> {
        let mut inner = self.inner.lock().unwrap();
        inner.seen.insert(user.to_string());
        let max_qps = inner.quotas.get(user).unwrap_or(&inner.default_quota).max_qps;
        let window = inner.requests.entry(user.to_string()).or_default();
        let floor = now_ms.saturating_sub(QPS_WINDOW_MS - 1);
        window.retain(|&t| t >= floor);
        if max_qps > 0 && window.len() >= max_qps as usize {
            return Err(max_qps);
        }
        window.push(now_ms);
        Ok(())
    }

    /// Currently charged `(sessions, gpus)` held by `user`.
    pub fn occupancy(&self, user: &str) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        let mut sessions = 0;
        let mut gpus = 0;
        for (u, g) in inner.charged.values() {
            if u == user {
                sessions += 1;
                gpus += *g;
            }
        }
        (sessions, gpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_quota_is_unlimited() {
        let q = TenantQuota::default();
        assert_eq!(q.max_concurrent, 0);
        assert_eq!(q.max_gpus, 0);
        assert_eq!(q.gpu_second_budget, 0.0);
        assert_eq!(q.weight, 1);
        assert_eq!(q.class, PriorityClass::Normal);
        assert_eq!(q.max_qps, 0);
    }

    #[test]
    fn qps_window_slides_and_rejections_do_not_count() {
        let r = TenantRegistry::new(TenantQuota::default());
        r.set_quota("kim", TenantQuota { max_qps: 2, ..TenantQuota::default() });
        assert_eq!(r.try_request("kim", 100), Ok(()));
        assert_eq!(r.try_request("kim", 200), Ok(()));
        assert_eq!(r.try_request("kim", 300), Err(2));
        // Rejections are not counted: the window still clears when the
        // *admitted* requests age out, not later.
        assert_eq!(r.try_request("kim", 1099), Err(2));
        assert_eq!(r.try_request("kim", 1100), Ok(()));
        // Unlimited users are never throttled.
        for i in 0..100 {
            assert_eq!(r.try_request("lee", i), Ok(()));
        }
        assert!(r.users().contains(&"lee".to_string()));
    }

    #[test]
    fn overrides_shadow_the_default() {
        let r = TenantRegistry::new(TenantQuota { max_gpus: 8, ..TenantQuota::default() });
        assert_eq!(r.quota_of("kim").max_gpus, 8);
        r.set_quota("kim", TenantQuota { max_gpus: 2, ..TenantQuota::default() });
        assert_eq!(r.quota_of("kim").max_gpus, 2);
        // Other users still see the default.
        assert_eq!(r.quota_of("lee").max_gpus, 8);
        // Partial edits materialize from the default, not from zero.
        r.update_quota("lee", |q| q.weight = 4);
        let lee = r.quota_of("lee");
        assert_eq!(lee.weight, 4);
        assert_eq!(lee.max_gpus, 8);
        assert_eq!(r.overrides().len(), 2);
    }

    #[test]
    fn charge_and_release_are_idempotent() {
        let r = TenantRegistry::new(TenantQuota::default());
        r.charge("s1", "kim", 2);
        r.charge("s1", "kim", 5); // double charge ignored
        r.charge("s2", "kim", 1);
        assert_eq!(r.occupancy("kim"), (2, 3));
        assert_eq!(r.release("s1"), Some(("kim".to_string(), 2)));
        assert_eq!(r.release("s1"), None); // double release is a no-op
        assert_eq!(r.occupancy("kim"), (1, 1));
        assert_eq!(r.occupancy("lee"), (0, 0));
    }

    #[test]
    fn storage_bytes_overwrite_and_default_to_zero() {
        let r = TenantRegistry::new(TenantQuota::default());
        assert_eq!(r.storage_bytes_of("kim"), 0);
        r.set_storage_bytes("kim", 4096);
        assert_eq!(r.storage_bytes_of("kim"), 4096);
        // Absolute overwrite, not accumulation — GC recomputes.
        r.set_storage_bytes("kim", 1024);
        assert_eq!(r.storage_bytes_of("kim"), 1024);
        assert!(r.users().contains(&"kim".to_string()));
    }

    #[test]
    fn seen_users_accumulate() {
        let r = TenantRegistry::new(TenantQuota::default());
        r.note_user("b");
        r.charge("s", "a", 1);
        r.update_quota("c", |q| q.class = PriorityClass::High);
        assert_eq!(r.users(), vec!["a".to_string(), "b".to_string(), "c".to_string()]);
    }

    #[test]
    fn class_strings_round_trip() {
        for c in [PriorityClass::Low, PriorityClass::Normal, PriorityClass::High] {
            assert_eq!(PriorityClass::from_str(c.as_str()), Some(c));
        }
        assert_eq!(PriorityClass::from_str("frobnicate"), None);
        assert!(PriorityClass::High > PriorityClass::Normal);
        assert!(PriorityClass::Normal > PriorityClass::Low);
    }
}
