//! The weighted fair-share admission queue — which pending submission
//! is offered to the scheduler next.
//!
//! Submissions do not reach the scheduler's [`JobQueue`](crate::scheduler::JobQueue)
//! directly: they wait here, one FIFO lane per user, and a **stride
//! scheduler** picks across lanes. Every user carries a `pass` value;
//! admitting one of their jobs advances it by `STRIDE_SCALE / weight`,
//! so a weight-2 user is offered twice as many admissions as a
//! weight-1 user under contention. Users in a higher
//! [`PriorityClass`] always go first; ties inside a class break on
//! pass, then name (deterministic). A user whose lane was empty
//! re-enters at the minimum pass of the currently-waiting users — idle
//! time earns no credit.
//!
//! Selection is head-of-lane only: a user's own submissions stay FIFO,
//! but a blocked head (quota or capacity) lets *other users'* heads
//! through — the queue is work-conserving across users, and the
//! blocked user keeps its (minimal) pass so it is re-offered first
//! once the blocker clears.

use super::registry::PriorityClass;
use crate::scheduler::JobSpec;
use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Mutex;

/// Pass increment for a weight-1 admission; a user's stride is
/// `STRIDE_SCALE / weight`.
pub const STRIDE_SCALE: u64 = 1 << 16;

/// One submission waiting for admission.
#[derive(Debug, Clone)]
pub struct PendingAdmission {
    pub job: JobSpec,
    /// True for a preempted session re-entering the queue: it resumes
    /// from its checkpoint when re-admitted.
    pub resume: bool,
}

/// One `pop_next` pass's outcome.
#[derive(Debug)]
pub struct AdmitPop {
    /// The submission to offer to the scheduler, if any lane head was
    /// admissible.
    pub admitted: Option<PendingAdmission>,
    /// `(user, session)` pairs whose lane head was rejected for the
    /// *first* time this lifetime — the caller publishes one defer
    /// decision each (later rejections stay silent).
    pub deferred: Vec<(String, String)>,
}

#[derive(Default)]
struct Inner {
    /// Per-user FIFO lanes (only non-empty lanes are kept).
    lanes: BTreeMap<String, VecDeque<PendingAdmission>>,
    /// Stride passes; persists across lane drain/refill.
    passes: BTreeMap<String, u64>,
    /// Session ids already reported as deferred (one event per entry).
    deferred: BTreeSet<String>,
    len: usize,
}

/// Thread-safe fair-share queue (see module docs).
#[derive(Default)]
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
}

impl AdmissionQueue {
    pub fn new() -> AdmissionQueue {
        AdmissionQueue::default()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pending submissions waiting for `user`.
    pub fn depth_of(&self, user: &str) -> usize {
        self.inner.lock().unwrap().lanes.get(user).map(|q| q.len()).unwrap_or(0)
    }

    /// A clone of the submission at the head of `user`'s lane (the
    /// only candidate a selection pass would consider).
    pub fn head_of(&self, user: &str) -> Option<PendingAdmission> {
        self.inner.lock().unwrap().lanes.get(user).and_then(|q| q.front()).cloned()
    }

    /// Users with at least one pending submission.
    pub fn users_waiting(&self) -> Vec<String> {
        self.inner.lock().unwrap().lanes.keys().cloned().collect()
    }

    /// Queue a submission at the back of its user's lane.
    pub fn enqueue(&self, p: PendingAdmission) {
        self.enqueue_inner(p, false);
    }

    /// Queue at the *front* of the user's lane (preempted sessions keep
    /// their turn ahead of the user's own later submissions).
    pub fn enqueue_front(&self, p: PendingAdmission) {
        self.enqueue_inner(p, true);
    }

    fn enqueue_inner(&self, p: PendingAdmission, front: bool) {
        let mut inner = self.inner.lock().unwrap();
        let user = p.job.user.clone();
        if !inner.lanes.contains_key(&user) {
            // Re-entering after an idle spell: catch the pass up to the
            // waiting minimum so idle time never banks credit.
            let min_pass = inner
                .lanes
                .keys()
                .map(|u| inner.passes.get(u).copied().unwrap_or(0))
                .min();
            if let Some(m) = min_pass {
                let pass = inner.passes.entry(user.clone()).or_insert(0);
                if *pass < m {
                    *pass = m;
                }
            }
        }
        let lane = inner.lanes.entry(user).or_default();
        if front {
            lane.push_front(p);
        } else {
            lane.push_back(p);
        }
        inner.len += 1;
    }

    /// Remove a pending submission by session id (stop before
    /// admission). Returns whether anything was removed.
    pub fn remove(&self, id: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let mut hit = None;
        for (user, lane) in inner.lanes.iter_mut() {
            if let Some(pos) = lane.iter().position(|p| p.job.id == id) {
                lane.remove(pos);
                hit = Some((user.clone(), lane.is_empty()));
                break;
            }
        }
        match hit {
            Some((user, empty)) => {
                if empty {
                    inner.lanes.remove(&user);
                }
                inner.deferred.remove(id);
                inner.len -= 1;
                if inner.len == 0 {
                    inner.passes.clear(); // fully drained: clean slate
                }
                true
            }
            None => false,
        }
    }

    /// One fair-share selection pass. `meta` supplies each user's
    /// `(class, weight)`; `admissible` gates a lane head (quota +
    /// capacity — it must not call back into this queue). The first
    /// admissible head in (class desc, pass asc, name asc) order is
    /// popped and its user's pass advanced; rejected heads are
    /// reported in [`AdmitPop::deferred`] the first time only.
    pub fn pop_next(
        &self,
        meta: impl Fn(&str) -> (PriorityClass, u32),
        mut admissible: impl FnMut(&str, &PendingAdmission) -> bool,
    ) -> AdmitPop {
        let mut inner = self.inner.lock().unwrap();
        let mut deferred = Vec::new();
        let mut order: Vec<(PriorityClass, u64, String)> = inner
            .lanes
            .keys()
            .map(|u| {
                let (class, _) = meta(u);
                (class, inner.passes.get(u).copied().unwrap_or(0), u.clone())
            })
            .collect();
        order.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
        for (_, _, user) in order {
            let Some(head) = inner.lanes.get(&user).and_then(|q| q.front()).cloned() else {
                continue;
            };
            if admissible(&user, &head) {
                let lane = inner.lanes.get_mut(&user).expect("non-empty lane");
                let p = lane.pop_front().expect("lane head");
                if lane.is_empty() {
                    inner.lanes.remove(&user);
                }
                inner.len -= 1;
                inner.deferred.remove(&p.job.id);
                if inner.len == 0 {
                    // Fully drained: reset the pass plane, so the next
                    // burst starts fresh instead of a newcomer (pass 0)
                    // out-admitting a long-established user whose pass
                    // kept its absolute history.
                    inner.passes.clear();
                } else {
                    let (_, weight) = meta(&user);
                    let stride = STRIDE_SCALE / weight.max(1) as u64;
                    let pass = inner.passes.entry(user).or_insert(0);
                    *pass = pass.saturating_add(stride);
                }
                return AdmitPop { admitted: Some(p), deferred };
            }
            if inner.deferred.insert(head.job.id.clone()) {
                deferred.push((user, head.job.id));
            }
        }
        AdmitPop { admitted: None, deferred }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pending(user: &str, id: &str) -> PendingAdmission {
        PendingAdmission { job: JobSpec::new(id, 1).with_user(user), resume: false }
    }

    fn meta_table(
        table: &[(&str, PriorityClass, u32)],
    ) -> impl Fn(&str) -> (PriorityClass, u32) + '_ {
        move |user| {
            table
                .iter()
                .find(|(u, ..)| *u == user)
                .map(|(_, c, w)| (*c, *w))
                .unwrap_or((PriorityClass::Normal, 1))
        }
    }

    fn drain_order(q: &AdmissionQueue, meta: impl Fn(&str) -> (PriorityClass, u32)) -> Vec<String> {
        std::iter::from_fn(|| q.pop_next(&meta, |_, _| true).admitted)
            .map(|p| p.job.user)
            .collect()
    }

    #[test]
    fn equal_weights_alternate() {
        let q = AdmissionQueue::new();
        for i in 0..3 {
            q.enqueue(pending("a", &format!("a{}", i)));
        }
        for i in 0..3 {
            q.enqueue(pending("b", &format!("b{}", i)));
        }
        let order = drain_order(&q, |_| (PriorityClass::Normal, 1));
        assert_eq!(order, vec!["a", "b", "a", "b", "a", "b"]);
        assert!(q.is_empty());
    }

    #[test]
    fn weights_bias_the_interleave() {
        // Weight 2 vs 1: over 6 admissions "heavy" gets two for each
        // "light" one.
        let q = AdmissionQueue::new();
        for i in 0..4 {
            q.enqueue(pending("heavy", &format!("h{}", i)));
        }
        for i in 0..2 {
            q.enqueue(pending("light", &format!("l{}", i)));
        }
        let table = [("heavy", PriorityClass::Normal, 2), ("light", PriorityClass::Normal, 1)];
        let order = drain_order(&q, meta_table(&table));
        assert_eq!(order, vec!["heavy", "light", "heavy", "heavy", "light", "heavy"]);
    }

    #[test]
    fn higher_class_always_first() {
        let q = AdmissionQueue::new();
        q.enqueue(pending("norm", "n0"));
        q.enqueue(pending("vip", "v0"));
        q.enqueue(pending("vip", "v1"));
        let table = [("vip", PriorityClass::High, 1), ("norm", PriorityClass::Normal, 9)];
        let order = drain_order(&q, meta_table(&table));
        assert_eq!(order, vec!["vip", "vip", "norm"], "class beats weight");
    }

    #[test]
    fn blocked_head_defers_once_and_yields_to_peers() {
        let q = AdmissionQueue::new();
        q.enqueue(pending("a", "a0"));
        q.enqueue(pending("b", "b0"));
        let meta = |_: &str| (PriorityClass::Normal, 1);
        // a's head is blocked: b goes through; a0 is reported deferred
        // exactly once.
        let pop = q.pop_next(meta, |user, _| user != "a");
        assert_eq!(pop.admitted.as_ref().unwrap().job.user, "b");
        assert_eq!(pop.deferred, vec![("a".to_string(), "a0".to_string())]);
        let pop = q.pop_next(meta, |user, _| user != "a");
        assert!(pop.admitted.is_none());
        assert!(pop.deferred.is_empty(), "second rejection stays silent");
        // Unblocked: a0 finally admits.
        let pop = q.pop_next(meta, |_, _| true);
        assert_eq!(pop.admitted.unwrap().job.id, "a0");
    }

    #[test]
    fn front_enqueue_keeps_the_victims_turn() {
        let q = AdmissionQueue::new();
        q.enqueue(pending("a", "a0"));
        q.enqueue(pending("a", "a1"));
        q.enqueue_front(PendingAdmission { job: JobSpec::new("victim", 1).with_user("a"), resume: true });
        let meta = |_: &str| (PriorityClass::Normal, 1);
        let first = q.pop_next(meta, |_, _| true).admitted.unwrap();
        assert_eq!(first.job.id, "victim");
        assert!(first.resume);
        assert_eq!(q.pop_next(meta, |_, _| true).admitted.unwrap().job.id, "a0");
    }

    #[test]
    fn idle_user_earns_no_credit() {
        // "a" gets several admissions while "b" is absent; when "b"
        // arrives its pass catches up, so it does not monopolize.
        let q = AdmissionQueue::new();
        let meta = |_: &str| (PriorityClass::Normal, 1);
        for i in 0..4 {
            q.enqueue(pending("a", &format!("a{}", i)));
        }
        // Admit two of a's jobs (pass advances to 2 strides).
        assert_eq!(q.pop_next(meta, |_, _| true).admitted.unwrap().job.user, "a");
        assert_eq!(q.pop_next(meta, |_, _| true).admitted.unwrap().job.user, "a");
        for i in 0..3 {
            q.enqueue(pending("b", &format!("b{}", i)));
        }
        // b starts at a's pass, not zero: strict alternation follows.
        let order = drain_order(&q, meta);
        assert_eq!(order, vec!["a", "b", "a", "b", "b"]);
    }

    #[test]
    fn remove_by_id() {
        let q = AdmissionQueue::new();
        q.enqueue(pending("a", "a0"));
        q.enqueue(pending("a", "a1"));
        assert!(q.remove("a0"));
        assert!(!q.remove("a0"));
        assert_eq!(q.len(), 1);
        assert_eq!(q.depth_of("a"), 1);
        assert!(q.remove("a1"));
        assert!(q.users_waiting().is_empty());
    }
}
