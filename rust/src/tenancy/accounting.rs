//! Per-user GPU-second accounting, derived from the event bus.
//!
//! The accountant never sits on a training hot path: session code
//! publishes the `StateChanged` events it already publishes, and the
//! platform's consumer pump (the same subscription that feeds the
//! leaderboard and utilization monitor) forwards each event to
//! [`UsageAccountant::observe`]. A transition *into* `running` opens
//! an interval for the session; the first transition *out of*
//! `running` (paused, done, failed, stopped, queued) closes it and
//! adds `gpus × seconds` (virtual time) to the owner's total. Live
//! usage queries ([`UsageAccountant::usage_at`]) include still-open
//! intervals, so quota enforcement sees a long-running session's
//! consumption without waiting for it to stop.
//!
//! Session → (user, gpus) metadata is registered once at submission
//! (a control-path call); events for unregistered subjects are
//! ignored. Ring overflow can drop a closing event — the accountant
//! is deliberately lossy in the same way the utilization monitor is.
//! A dropped close would leave the interval accruing forever, so the
//! platform's consumer pump reconciles on overflow: every session
//! whose record is no longer `Running` gets its open interval closed
//! via [`UsageAccountant::close_if_open`] (at its recorded finish
//! time when known), bounding the error to the overflow window.

use crate::events::{Event, EventKind};
use crate::util::clock::Millis;
use std::collections::BTreeMap;
use std::sync::Mutex;

#[derive(Default)]
struct Inner {
    /// session -> (user, gpus), registered at submission.
    meta: BTreeMap<String, (String, usize)>,
    /// session -> running-since (virtual ms) for open intervals.
    open: BTreeMap<String, Millis>,
    /// user -> closed GPU-seconds.
    closed: BTreeMap<String, f64>,
}

/// Thread-safe GPU-second ledger (see module docs).
#[derive(Default)]
pub struct UsageAccountant {
    inner: Mutex<Inner>,
}

impl UsageAccountant {
    pub fn new() -> UsageAccountant {
        UsageAccountant::default()
    }

    /// Register a session's owner and GPU count (called once at
    /// submission, before any of its state events can publish).
    pub fn register(&self, session: &str, user: &str, gpus: usize) {
        self.inner
            .lock()
            .unwrap()
            .meta
            .insert(session.to_string(), (user.to_string(), gpus.max(1)));
    }

    /// Feed one bus event through the ledger (only `state` events
    /// matter; everything else is a cheap no-op).
    pub fn observe(&self, e: &Event) {
        let EventKind::StateChanged { to, .. } = &e.kind else {
            return;
        };
        let mut inner = self.inner.lock().unwrap();
        if to == "running" {
            if inner.meta.contains_key(&e.subject) && !inner.open.contains_key(&e.subject) {
                inner.open.insert(e.subject.clone(), e.at_ms);
            }
        } else if let Some(since) = inner.open.remove(&e.subject) {
            let (user, gpus) =
                inner.meta.get(&e.subject).cloned().expect("open interval implies meta");
            let add = e.at_ms.saturating_sub(since) as f64 / 1000.0 * gpus as f64;
            *inner.closed.entry(user).or_insert(0.0) += add;
        }
    }

    /// Close `session`'s open interval at `at_ms` if one exists
    /// (overflow reconciliation: the exit event was lost, but the
    /// session record proves it stopped running). No-op otherwise.
    pub fn close_if_open(&self, session: &str, at_ms: Millis) {
        let mut inner = self.inner.lock().unwrap();
        if let Some(since) = inner.open.remove(session) {
            let (user, gpus) = inner.meta.get(session).cloned().expect("open interval implies meta");
            let add = at_ms.saturating_sub(since) as f64 / 1000.0 * gpus as f64;
            *inner.closed.entry(user).or_insert(0.0) += add;
        }
    }

    /// Snapshot the ledger for durability: per-user closed GPU-second
    /// totals plus the still-open `(session, running-since)` intervals.
    /// The pre-snapshot WAL segment is rotated away, so anything not
    /// captured here would be lost across a restart.
    pub fn dump(&self) -> (Vec<(String, f64)>, Vec<(String, Millis)>) {
        let inner = self.inner.lock().unwrap();
        (
            inner.closed.iter().map(|(u, s)| (u.clone(), *s)).collect(),
            inner.open.iter().map(|(s, t)| (s.clone(), *t)).collect(),
        )
    }

    /// Rebuild the ledger from a snapshot [`dump`](Self::dump). Meta
    /// must already be registered: open intervals for unregistered
    /// sessions are dropped (they could never close safely).
    pub fn restore(&self, closed: &[(String, f64)], open: &[(String, Millis)]) {
        let mut inner = self.inner.lock().unwrap();
        for (user, secs) in closed {
            *inner.closed.entry(user.clone()).or_insert(0.0) += *secs;
        }
        for (session, since) in open {
            if inner.meta.contains_key(session) && !inner.open.contains_key(session) {
                inner.open.insert(session.clone(), *since);
            }
        }
    }

    /// `user`'s total GPU-seconds as of `now_ms` — closed intervals
    /// plus every interval still running.
    pub fn usage_at(&self, user: &str, now_ms: Millis) -> f64 {
        let inner = self.inner.lock().unwrap();
        let mut total = inner.closed.get(user).copied().unwrap_or(0.0);
        for (session, since) in &inner.open {
            if let Some((u, gpus)) = inner.meta.get(session) {
                if u == user {
                    total += now_ms.saturating_sub(*since) as f64 / 1000.0 * *gpus as f64;
                }
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::events::Level;

    fn state(subject: &str, to: &str, at_ms: Millis) -> Event {
        Event {
            seq: 0,
            at_ms,
            level: Level::Info,
            source: "session".into(),
            subject: subject.to_string(),
            kind: EventKind::StateChanged { from: "x".into(), to: to.to_string(), step: 0 },
        }
    }

    #[test]
    fn intervals_accumulate_gpu_seconds() {
        let acc = UsageAccountant::new();
        acc.register("s1", "kim", 2);
        acc.observe(&state("s1", "running", 1_000));
        // Live usage includes the open interval.
        assert!((acc.usage_at("kim", 3_000) - 4.0).abs() < 1e-9, "2 gpus x 2s");
        acc.observe(&state("s1", "paused", 4_000));
        assert!((acc.usage_at("kim", 9_999) - 6.0).abs() < 1e-9, "closed at 3s x 2 gpus");
        // Resume opens a fresh interval.
        acc.observe(&state("s1", "running", 10_000));
        acc.observe(&state("s1", "done", 11_000));
        assert!((acc.usage_at("kim", 99_999) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_sessions_and_other_users_ignored() {
        let acc = UsageAccountant::new();
        acc.observe(&state("ghost", "running", 0));
        acc.observe(&state("ghost", "done", 5_000));
        assert_eq!(acc.usage_at("anyone", 10_000), 0.0);
        acc.register("s1", "kim", 1);
        acc.observe(&state("s1", "running", 0));
        assert_eq!(acc.usage_at("lee", 10_000), 0.0);
        assert!((acc.usage_at("kim", 10_000) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn lost_exit_event_is_reconcilable() {
        // A ring overflow ate the 'done' transition: close_if_open
        // settles the interval at the recorded finish time instead of
        // letting it accrue forever.
        let acc = UsageAccountant::new();
        acc.register("s1", "kim", 2);
        acc.observe(&state("s1", "running", 1_000));
        acc.close_if_open("s1", 3_000);
        assert!((acc.usage_at("kim", 999_999) - 4.0).abs() < 1e-9, "2 gpus x 2s, then frozen");
        // Idempotent; and a no-op for sessions without an open interval.
        acc.close_if_open("s1", 9_000);
        acc.close_if_open("ghost", 9_000);
        assert!((acc.usage_at("kim", 999_999) - 4.0).abs() < 1e-9);
    }

    #[test]
    fn dump_restore_round_trips_closed_and_open() {
        let acc = UsageAccountant::new();
        acc.register("s1", "kim", 2);
        acc.register("s2", "lee", 1);
        acc.observe(&state("s1", "running", 1_000));
        acc.observe(&state("s1", "done", 3_000)); // kim: 4 closed
        acc.observe(&state("s2", "running", 2_000)); // lee: open
        let (closed, open) = acc.dump();

        let fresh = UsageAccountant::new();
        fresh.register("s2", "lee", 1);
        fresh.restore(&closed, &open);
        assert!((fresh.usage_at("kim", 99_999) - 4.0).abs() < 1e-9);
        // Open interval survived and keeps accruing.
        assert!((fresh.usage_at("lee", 5_000) - 3.0).abs() < 1e-9);
        // Open intervals without registered meta are dropped, not
        // resurrected as unclosable ghosts.
        let bare = UsageAccountant::new();
        bare.restore(&closed, &open);
        assert_eq!(bare.usage_at("lee", 99_999), 0.0);
        assert!((bare.usage_at("kim", 0) - 4.0).abs() < 1e-9);
        bare.observe(&state("s2", "done", 9_000)); // must not panic
    }

    #[test]
    fn duplicate_transitions_are_safe() {
        let acc = UsageAccountant::new();
        acc.register("s1", "kim", 1);
        acc.observe(&state("s1", "running", 1_000));
        acc.observe(&state("s1", "running", 2_000)); // keeps the original start
        acc.observe(&state("s1", "done", 3_000));
        acc.observe(&state("s1", "done", 9_000)); // no open interval: no-op
        assert!((acc.usage_at("kim", 99_999) - 2.0).abs() < 1e-9);
    }
}
