//! `nsml` — the NSML command line (paper §3.4, Fig. 2).
//!
//! `nsml [OPTIONS] COMMAND [ARGS]...` with the paper's commands:
//!
//! * `nsml run -d DATASET`          — pack code, submit, train, report
//! * `nsml pause SESSION`           — checkpoint + pause a running session
//! * `nsml resume SESSION [--lr X]` — resume, optionally with a new lr (§3.3)
//! * `nsml stop SESSION`            — stop a session outright
//! * `nsml dataset ls`              — list datasets
//! * `nsml dataset board DATASET`   — the kaggle-like leaderboard
//! * `nsml ps` / `nsml logs [-f]` / `nsml plot SESSION`
//! * `nsml infer SESSION`           — interactive digit demo (Fig. 4)
//! * `nsml automl -d DATASET`       — hyperparameter search
//! * `nsml tenants` / `nsml quota USER [--max-gpus N …]` — fair-share
//!   status and per-user quota edits (weights, classes, budgets)
//! * `nsml promote NAME SESSION` / `nsml endpoints` — promote a
//!   session's best checkpoint to a named serving endpoint (roll
//!   forward/back/retire with `--action`) and list the registry
//! * `nsml gc [--status]`          — sweep orphaned objects (or print
//!   the WAL/snapshot/GC durability counters)
//! * `nsml cluster` / `nsml models` / `nsml web`
//! * `nsml serve`                   — always-on service mode: a background
//!   drive loop plus the pooled keep-alive HTTP front end with SSE
//!
//! Session-control subcommands build [`crate::api::ApiRequest`]s and go
//! through [`crate::api::PlatformService::dispatch`] — the same wire
//! surface the web UI's `POST /api/v1/*` routes use — then render the
//! typed [`crate::api::ApiResponse`]. CLI invocations compose through
//! the state directory (default `.nsml`), which plays the role of NSML's
//! always-on cloud.

mod commands;

use crate::util::argparse::{split_subcommand, ArgSpec};

const USAGE: &str = "nsml — NAVER Smart Machine Learning (reproduction)

USAGE: nsml COMMAND [ARGS]...

COMMANDS:
  run        submit and train a session:  nsml run main.py -d mnist
  pause      pause a running session:     nsml pause SESSION
  resume     resume a paused session:     nsml resume SESSION --lr 0.05
  stop       stop a session outright:     nsml stop SESSION
  dataset    manage datasets:             nsml dataset ls | board DATASET
  ps         list sessions
  logs       show a session's event log:  nsml logs SESSION [-f]
             (-f follows: drives training and streams new events)
  plot       ASCII learning curves:       nsml plot SESSION
  infer      interactive MNIST demo:      nsml infer SESSION --digit 1 --add-lines
  automl     hyperparameter search:       nsml automl -d mnist --strategy asha
  cluster    cluster & scheduler status
  tenants    per-user fair-share status (quotas, GPU-seconds, queue)
  quota      show or set a user's quota:  nsml quota kim --max-gpus 4 --weight 2
  promote    promote a checkpoint to a serving endpoint:
             nsml promote NAME SESSION [--action rollback|rollforward|retire]
  endpoints  list serving endpoints (active version + history)
  gc         sweep orphaned objects:      nsml gc [--status]
  metrics    platform metrics report (counters, gauges, latency quantiles)
  trace      spans recorded under a trace id: nsml trace TRACE_ID
  models     list AOT-compiled models
  web        serve the web UI:            nsml web --port 8080
  serve      always-on service mode:      nsml serve --port 8080
             (background drive loop + pooled HTTP front end + SSE)

Global options (before or after COMMAND args):
  --state DIR      state directory [default: .nsml]
  --artifacts DIR  AOT artifacts [default: artifacts]
";

/// CLI entry point; returns the process exit code.
pub fn main(args: &[String]) -> i32 {
    let (cmd, rest) = split_subcommand(args);
    let result = match cmd.as_str() {
        "run" => commands::cmd_run(&rest),
        "pause" => commands::cmd_pause(&rest),
        "resume" => commands::cmd_resume(&rest),
        "stop" => commands::cmd_stop(&rest),
        "dataset" => commands::cmd_dataset(&rest),
        "ps" => commands::cmd_ps(&rest),
        "logs" => commands::cmd_logs(&rest),
        "plot" => commands::cmd_plot(&rest),
        "infer" => commands::cmd_infer(&rest),
        "automl" => commands::cmd_automl(&rest),
        "cluster" => commands::cmd_cluster(&rest),
        "tenants" => commands::cmd_tenants(&rest),
        "quota" => commands::cmd_quota(&rest),
        "promote" => commands::cmd_promote(&rest),
        "endpoints" => commands::cmd_endpoints(&rest),
        "gc" => commands::cmd_gc(&rest),
        "metrics" => commands::cmd_metrics(&rest),
        "trace" => commands::cmd_trace(&rest),
        "models" => commands::cmd_models(&rest),
        "web" => commands::cmd_web(&rest),
        "serve" => commands::cmd_serve(&rest),
        "" | "help" | "--help" | "-h" => {
            println!("{}", USAGE);
            Ok(())
        }
        other => Err(format!("unknown command '{}'\n\n{}", other, USAGE)),
    };
    match result {
        Ok(()) => 0,
        Err(msg) => {
            eprintln!("{}", msg);
            1
        }
    }
}

/// Shared global flags for subcommands.
pub(crate) fn with_globals(spec: ArgSpec) -> ArgSpec {
    spec.opt("state", None, "state directory", Some(".nsml"))
        .opt("artifacts", None, "AOT artifacts directory", Some("artifacts"))
}
