//! Implementations of the `nsml` subcommands. Session-control commands
//! build [`ApiRequest`]s, dispatch them through the [`PlatformService`],
//! and render the typed [`ApiResponse`] — the CLI is a wire-format
//! client, exactly like the web UI's `POST /api/v1/*` routes.

use super::with_globals;
use crate::api::{
    ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig, PlatformService,
    PlatformTrialRunner, RunParams,
};
use crate::automl::{log_grid, GridSearch, RandomSearch, SuccessiveHalving};
use crate::data::digits::{ascii_digit, draw_digit, DIM};
use crate::storage::codepack;
use crate::util::argparse::{ArgSpec, Parsed};
use crate::util::plot::ascii_chart;
use crate::util::table::{fms, fnum, Table};
use std::path::PathBuf;

type CmdResult = Result<(), String>;

fn platform_from(parsed: &Parsed) -> Result<NsmlPlatform, String> {
    let cfg = PlatformConfig {
        artifacts_dir: PathBuf::from(parsed.get("artifacts").unwrap_or("artifacts")),
        state_dir: Some(PathBuf::from(parsed.get("state").unwrap_or(".nsml"))),
        // CLI runs use the fast latency model so virtual costs are
        // visible in the logs without 45-s real stalls.
        latency: crate::container::LatencyModel::fast(),
        ..PlatformConfig::default()
    };
    NsmlPlatform::new(cfg).map_err(|e| format!("platform init: {:#}", e))
}

fn service_from(parsed: &Parsed) -> Result<PlatformService, String> {
    Ok(PlatformService::new(platform_from(parsed)?))
}

/// Unwrap a dispatch reply: error envelopes become the command error.
fn ok(resp: ApiResponse) -> Result<ApiResponse, String> {
    resp.into_result().map_err(|e| e.to_string())
}

// ---------------------------------------------------------------------
// nsml run
// ---------------------------------------------------------------------

pub fn cmd_run(args: &[String]) -> CmdResult {
    let spec = with_globals(
        ArgSpec::new("nsml run", "pack code, submit a session, train, report")
            .pos("entry", "entry file (packed with the code dir)", false)
            .opt("dataset", Some('d'), "dataset to mount", None)
            .opt("gpus", Some('g'), "GPUs to request", Some("1"))
            .opt("steps", None, "total training steps", Some("200"))
            .opt("lr", None, "learning rate (default: model's)", None)
            .opt("seed", None, "init seed", Some("0"))
            .opt("user", Some('u'), "submitting user", Some("researcher"))
            .opt("priority", None, "low|normal|high", Some("normal"))
            .flag("scan", None, "use the scan-fused train path")
            .flag("quiet", Some('q'), "suppress the curve printout"),
    );
    let p = spec.parse(args)?;
    let dataset = p.get("dataset").ok_or("missing --dataset (-d)")?.to_string();
    let service = service_from(&p)?;

    // Pack the "user code" exactly like NSML-CLI does before submitting.
    let entry = p.pos(0).unwrap_or("main.py");
    let code: Vec<(&str, &[u8])> = vec![(entry, b"# packed by nsml-cli (reproduction)\n".as_slice())];
    let code_id = codepack::store_codepack(&service.platform().objects, &code).map_err(|e| e.to_string())?;

    let steps = p.get_usize("steps")? as u64;
    let mut params = RunParams::new(p.get("user").unwrap(), &dataset);
    params.gpus = p.get_usize("gpus")?;
    params.total_steps = steps;
    params.lr = p.get("lr").map(|s| s.parse().map_err(|e| format!("--lr: {}", e))).transpose()?;
    params.seed = p.get_usize("seed")? as u64;
    params.use_scan = p.flag("scan");
    params.priority = p.get("priority").unwrap_or("normal").to_string();
    params.checkpoint_every = (steps / 4).max(1);
    params.eval_every = (steps / 8).max(1);

    let id = match ok(service.dispatch(ApiRequest::Run(params)))? {
        ApiResponse::Submitted { session } => session,
        other => return Err(format!("unexpected reply to run: {:?}", other)),
    };
    println!("session: {}  (code {})", id, code_id);
    ok(service.dispatch(ApiRequest::RunToCompletion { chunk: 25, max_rounds: 100_000 }))?;
    let platform = service.platform();
    platform.save_state().map_err(|e| format!("{:#}", e))?;

    let rec = platform.sessions.get(&id).unwrap();
    println!(
        "state: {}  steps: {}  best {}: {}",
        rec.state.as_str(),
        rec.steps_done,
        platform.engine().manifest().model(&rec.spec.model).map(|m| m.metric_name.clone()).unwrap_or_default(),
        rec.best_metric.map(fnum).unwrap_or_else(|| "-".into()),
    );
    if !p.flag("quiet") {
        let series = rec.metrics.plot_series("train_loss");
        println!("{}", ascii_chart(&format!("{} train_loss", id), &[series], 64, 14));
    }
    println!("{}", platform.leaderboard.render(&dataset));
    Ok(())
}

// ---------------------------------------------------------------------
// nsml pause / resume / stop — session control through the service (§3.3)
// ---------------------------------------------------------------------

pub fn cmd_pause(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml pause", "checkpoint and pause a running session")
            .pos("session", "session id", true),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let session = p.pos(0).unwrap().to_string();
    ack(&service, service.dispatch(ApiRequest::Pause { session }))
}

pub fn cmd_resume(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml resume", "resume a paused session, optionally with a new lr")
            .pos("session", "session id", true)
            .opt("lr", None, "new learning rate (in-training tuning)", None),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let session = p.pos(0).unwrap().to_string();
    let lr = p.get("lr").map(|s| s.parse().map_err(|e| format!("--lr: {}", e))).transpose()?;
    ack(&service, service.dispatch(ApiRequest::Resume { session, lr }))
}

pub fn cmd_stop(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml stop", "stop a session outright").pos("session", "session id", true),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let session = p.pos(0).unwrap().to_string();
    ack(&service, service.dispatch(ApiRequest::Stop { session }))
}

/// Render an `Ack` reply and persist the resulting state.
fn ack(service: &PlatformService, resp: ApiResponse) -> CmdResult {
    match ok(resp)? {
        ApiResponse::Ack { verb, session } => {
            println!("{}: ok{}", verb, session.map(|s| format!(" ({})", s)).unwrap_or_default());
            service.platform().save_state().map_err(|e| format!("{:#}", e))?;
            Ok(())
        }
        other => Err(format!("unexpected reply: {:?}", other)),
    }
}

// ---------------------------------------------------------------------
// nsml dataset
// ---------------------------------------------------------------------

pub fn cmd_dataset(args: &[String]) -> CmdResult {
    let (sub, rest) = crate::util::argparse::split_subcommand(args);
    match sub.as_str() {
        "ls" | "" => {
            let p = with_globals(ArgSpec::new("nsml dataset ls", "list datasets")).parse(&rest)?;
            let platform = platform_from(&p)?;
            let mut t = Table::new(&["NAME", "OWNER", "VERSION", "SIZE(GB)", "DESCRIPTION"]).right(&[2, 3]);
            for d in platform.datasets.list("anyone") {
                t.row(&[
                    d.name.clone(),
                    d.owner.clone(),
                    format!("v{}", d.version),
                    format!("{:.1}", d.nominal_size_gb),
                    d.description.clone(),
                ]);
            }
            println!("{}", t.render());
            Ok(())
        }
        "board" => {
            let p = with_globals(
                ArgSpec::new("nsml dataset board", "show a dataset leaderboard")
                    .pos("dataset", "dataset name", true)
                    .opt("user", Some('u'), "only this user's rows (global ranks kept)", None),
            )
            .parse(&rest)?;
            let service = service_from(&p)?;
            let dataset = p.pos(0).unwrap().to_string();
            let req =
                ApiRequest::Board { dataset, limit: 100, user: p.get("user").map(str::to_string) };
            match ok(service.dispatch(req))? {
                ApiResponse::Board { dataset, rows } => {
                    let mut t = Table::new(&["RANK", "SESSION", "USER", "MODEL", "METRIC", "VALUE", "STEP"])
                        .right(&[0, 5, 6]);
                    for r in &rows {
                        t.row(&[
                            format!("{}", r.rank),
                            r.session.clone(),
                            r.user.clone(),
                            r.model.clone(),
                            r.metric.clone(),
                            fnum(r.value),
                            format!("{}", r.step),
                        ]);
                    }
                    if t.is_empty() {
                        println!("leaderboard '{}' has no entries yet", dataset);
                    } else {
                        println!("{}", t.render());
                    }
                    Ok(())
                }
                other => Err(format!("unexpected reply: {:?}", other)),
            }
        }
        other => Err(format!("unknown dataset subcommand '{}' (ls | board)", other)),
    }
}

// ---------------------------------------------------------------------
// nsml ps / logs / plot
// ---------------------------------------------------------------------

pub fn cmd_ps(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new("nsml ps", "list sessions")).parse(args)?;
    let service = service_from(&p)?;
    let views = match ok(service.dispatch(ApiRequest::list_sessions()))? {
        ApiResponse::Sessions { sessions } => sessions,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    let mut t = Table::new(&["SESSION", "MODEL", "STATE", "STEPS", "BEST", "RECOVERIES"]).right(&[3, 4, 5]);
    for v in &views {
        t.row(&[
            v.id.clone(),
            v.model.clone(),
            v.state.as_str().to_string(),
            format!("{}/{}", v.steps_done, v.total_steps),
            v.best_metric.map(fnum).unwrap_or_else(|| "-".into()),
            format!("{}", v.recoveries),
        ]);
    }
    if t.is_empty() {
        println!("no sessions (run `nsml run -d mnist` first)");
    } else {
        println!("{}", t.render());
    }
    Ok(())
}

pub fn cmd_logs(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml logs", "show session events")
            .pos("session", "session id", true)
            .flag("follow", Some('f'), "drive the platform and stream events until done")
            .opt("chunk", None, "steps per drive round in follow mode", Some("25")),
    )
    .parse(args)?;
    let platform = platform_from(&p)?;
    let id = p.pos(0).unwrap();
    let rec = platform.sessions.get(id).ok_or_else(|| format!("no session '{}'", id))?;
    println!("session {} — state {}", id, rec.state.as_str());

    // A polling subscription over the event bus: history replays first,
    // then each follow round prints only what that round published.
    let mut sub = platform
        .events
        .bus()
        .subscribe_from_start()
        .with_filter(crate::events::EventFilter::default().with_subject(id));
    for e in sub.poll() {
        println!("{}", e.render());
    }

    if p.flag("follow") {
        let chunk = p.get_usize("chunk")?.max(1) as u64;
        // Same safety cap as run_to_completion: a session starved by
        // paused peers must not spin this loop forever.
        for _ in 0..100_000u32 {
            let Some(rec) = platform.sessions.get(id) else { break };
            if rec.state.is_terminal() {
                break;
            }
            if rec.state == crate::session::SessionState::Paused {
                println!("(session is paused — resume it to continue following)");
                break;
            }
            // drive_round keeps virtual-time heartbeats/leases alive
            // between rounds, exactly like run_to_completion.
            platform.drive_round(chunk).map_err(|e| format!("{:#}", e))?;
            for e in sub.poll() {
                println!("{}", e.render());
            }
        }
        if sub.dropped() > 0 {
            eprintln!("({} events dropped: ring overflow while following)", sub.dropped());
        }
        platform.save_state().map_err(|e| format!("{:#}", e))?;
        if let Some(rec) = platform.sessions.get(id) {
            println!("session {} — state {}", id, rec.state.as_str());
        }
    }

    let rec = platform.sessions.get(id).ok_or_else(|| format!("no session '{}'", id))?;
    for pt in rec.metrics.points().iter().rev().take(10).rev() {
        println!("  step {:>6}  {:<12} {}", pt.step, pt.name, fnum(pt.value));
    }
    Ok(())
}

pub fn cmd_plot(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml plot", "ASCII learning curves")
            .pos("session", "session id", true)
            .opt("metric", Some('m'), "metric name (default: all)", None),
    )
    .parse(args)?;
    let platform = platform_from(&p)?;
    let id = p.pos(0).unwrap();
    let rec = platform.sessions.get(id).ok_or_else(|| format!("no session '{}'", id))?;
    let names = match p.get("metric") {
        Some(m) => vec![m.to_string()],
        None => rec.metrics.names(),
    };
    for name in names {
        let series = rec.metrics.plot_series(&name);
        if series.points.is_empty() {
            println!("(no points for metric '{}')", name);
            continue;
        }
        println!("{}", ascii_chart(&format!("{} {}", id, name), &[series], 64, 12));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// nsml infer — the Fig. 4 interactive demo
// ---------------------------------------------------------------------

pub fn cmd_infer(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml infer", "classify a drawn digit with a trained session")
            .pos("session", "session id (an mnist session)", true)
            .opt("digit", None, "digit to draw", Some("1"))
            .flag("add-lines", None, "then add the 2's extra strokes (Fig. 4)"),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let id = p.pos(0).unwrap();
    let digit = p.get_usize("digit")?.min(9);

    let mut img = vec![0.0f32; DIM];
    draw_digit(digit, 0, 0, 1.0, &mut img);
    println!("input:\n{}", ascii_digit(&img));
    let probs = classify(&service, id, &img)?;
    print_probs(&probs);

    if p.flag("add-lines") {
        // Overlay the segments of '2' that the current digit lacks.
        let mut two = vec![0.0f32; DIM];
        draw_digit(2, 0, 0, 1.0, &mut two);
        for (a, b) in img.iter_mut().zip(&two) {
            *a = a.max(*b);
        }
        println!("after adding lines:\n{}", ascii_digit(&img));
        let probs = classify(&service, id, &img)?;
        print_probs(&probs);
    }
    Ok(())
}

fn classify(service: &PlatformService, session: &str, img: &[f32]) -> Result<Vec<f32>, String> {
    let req = ApiRequest::Infer {
        session: session.to_string(),
        x: img.repeat(64), // model batch is fixed at 64
        shape: vec![64, DIM as i64],
    };
    match ok(service.dispatch(req))? {
        ApiResponse::Probs { probs } => Ok(probs[..10].to_vec()),
        other => Err(format!("unexpected reply: {:?}", other)),
    }
}

fn print_probs(probs: &[f32]) {
    let argmax = probs.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0;
    for (i, p) in probs.iter().enumerate() {
        let bar = "█".repeat((p * 40.0) as usize);
        println!("  {} {:>6.3} {}{}", i, p, bar, if i == argmax { "  <-- prediction" } else { "" });
    }
    println!();
}

// ---------------------------------------------------------------------
// nsml automl
// ---------------------------------------------------------------------

pub fn cmd_automl(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml automl", "hyperparameter search over real sessions")
            .opt("dataset", Some('d'), "dataset", Some("mnist"))
            .opt("strategy", Some('s'), "grid|random|asha", Some("asha"))
            .opt("candidates", Some('c'), "number of candidates", Some("6"))
            .opt("steps", None, "full budget per trial", Some("60"))
            .opt("seed", None, "search seed", Some("0"))
            .opt("user", Some('u'), "user", Some("automl")),
    )
    .parse(args)?;
    let platform = platform_from(&p)?;
    let dataset = p.get("dataset").unwrap().to_string();
    let candidates = p.get_usize("candidates")?;
    let steps = p.get_usize("steps")? as u64;
    let seed = p.get_usize("seed")? as u64;

    // Trials train inside their own executor pool (one worker per
    // configured executor thread), so rungs run cluster-parallel.
    let mut runner = PlatformTrialRunner::new(
        platform.new_trial_pool(),
        &dataset,
        p.get("user").unwrap(),
        platform.sessions.clone(),
        platform.clock.clone(),
        candidates,
        seed,
    )
    .map_err(|e| format!("{:#}", e))?;

    let lrs = log_grid(candidates, -3.5, 0.5);
    let strategy = p.get("strategy").unwrap().to_string();
    let out = match strategy.as_str() {
        "grid" => GridSearch { lrs, steps_per_trial: steps }.run(&mut runner),
        "random" => RandomSearch {
            candidates,
            lr_log10_range: (-3.5, 0.5),
            steps_per_trial: steps,
            probe_frac: 0.2,
            seed,
        }
        .run(&mut runner),
        _ => SuccessiveHalving { lrs, total_steps_per_trial: steps, eta: 2, rungs: 3 }.run(&mut runner),
    };

    let mut t = Table::new(&["TRIAL", "LR", "LOSS", "STEPS GIVEN"]).right(&[1, 2, 3]);
    for (i, (lr, loss, given)) in out.trials.iter().enumerate() {
        let mark = if i == out.best_trial { " *" } else { "" };
        t.row(&[format!("{}{}", i, mark), fnum(*lr), fnum(*loss), format!("{}", given)]);
    }
    println!("strategy: {}   budget spent: {} steps (vs {} exhaustive)", strategy, out.steps_spent, candidates as u64 * steps);
    println!("{}", t.render());
    let ck = runner.save_best(out.best_trial).map_err(|e| format!("{:#}", e))?;
    println!("best model saved: trial {} lr={} -> checkpoint step {} ({})", out.best_trial, fnum(out.best_lr), ck.step, ck.params);
    platform.save_state().map_err(|e| format!("{:#}", e))?;
    Ok(())
}

// ---------------------------------------------------------------------
// nsml cluster / models / web
// ---------------------------------------------------------------------

pub fn cmd_cluster(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new("nsml cluster", "cluster & scheduler status")).parse(args)?;
    let service = service_from(&p)?;
    let view = match ok(service.dispatch(ApiRequest::ClusterStatus))? {
        ApiResponse::Cluster { cluster } => cluster,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    println!(
        "cluster: {} nodes, {} GPUs ({} free) | scheduler: {} (fast_path={}) | leader: {:?} epoch {} | queue {}",
        view.nodes.len(),
        view.total_gpus,
        view.free_gpus,
        view.policy,
        view.fast_path,
        view.leader,
        view.epoch,
        view.queue_len,
    );
    let mut t = Table::new(&["NODE", "ALIVE", "GPUS FREE", "JOBS"]).right(&[2]);
    for n in &view.nodes {
        t.row(&[
            n.hostname.clone(),
            format!("{}", n.alive),
            format!("{}/{}", n.free_gpus, n.total_gpus),
            n.jobs.join(","),
        ]);
    }
    println!("{}", t.render());

    // Executor-pool telemetry: per-worker load + steal counters.
    let ex = match ok(service.dispatch(ApiRequest::ExecutorStatus))? {
        ApiResponse::Executor { executor } => executor,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    println!(
        "executor: {} workers (work_steal={}) | live {} | queued {} | steals {}",
        ex.workers.len(),
        ex.work_steal,
        ex.live_sessions,
        ex.queue_depth,
        ex.total_steals,
    );
    let mut t = Table::new(&["WORKER", "BUSY", "LIVE", "QUEUE", "STEALS"]).right(&[1, 2, 3, 4]);
    for w in &ex.workers {
        t.row(&[
            format!("w{}", w.worker),
            fms(w.busy_ms),
            format!("{}", w.live_sessions),
            format!("{}", w.queue_depth),
            format!("{}", w.steals),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

// ---------------------------------------------------------------------
// nsml tenants / quota — multi-tenant fair share
// ---------------------------------------------------------------------

pub fn cmd_tenants(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new("nsml tenants", "per-user fair-share status")).parse(args)?;
    let service = service_from(&p)?;
    let views = match ok(service.dispatch(ApiRequest::TenantReport))? {
        ApiResponse::Tenants { tenants } => tenants,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    if views.is_empty() {
        println!("no tenants yet (run `nsml run -d mnist` first)");
        return Ok(());
    }
    let mut t = Table::new(&[
        "USER", "CLASS", "WEIGHT", "ACTIVE", "GPUS", "WAITING", "GPU-SEC", "BUDGET", "PREEMPTS",
    ])
    .right(&[2, 3, 4, 5, 6, 7, 8]);
    for v in &views {
        t.row(&[
            v.user.clone(),
            v.class.clone(),
            format!("{}", v.weight),
            format!("{}", v.active_sessions),
            format!("{}", v.gpus_in_use),
            format!("{}", v.waiting),
            fnum(v.gpu_seconds_used),
            if v.gpu_second_budget > 0.0 { fnum(v.gpu_second_budget) } else { "-".into() },
            format!("{}", v.preemptions),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

pub fn cmd_quota(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml quota", "show or set a user's fair-share quota")
            .pos("user", "tenant user name", true)
            .opt("max-concurrent", None, "max concurrent sessions (0 = unlimited)", None)
            .opt("max-gpus", None, "max GPUs held at once (0 = unlimited)", None)
            .opt("budget", None, "GPU-second budget (0 = unlimited)", None)
            .opt("weight", None, "fair-share weight (>= 1)", None)
            .opt("class", None, "priority class: low|normal|high", None)
            .opt("max-qps", None, "max serving requests/sec (0 = unlimited)", None),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let user = p.pos(0).unwrap().to_string();
    let parse_u = |key: &str| -> Result<Option<u64>, String> {
        p.get(key).map(|s| s.parse::<u64>().map_err(|e| format!("--{}: {}", key, e))).transpose()
    };
    let max_concurrent = parse_u("max-concurrent")?;
    let max_gpus = parse_u("max-gpus")?;
    let weight = parse_u("weight")?;
    let budget = p
        .get("budget")
        .map(|s| s.parse::<f64>().map_err(|e| format!("--budget: {}", e)))
        .transpose()?;
    let class = p.get("class").map(str::to_string);
    let max_qps = parse_u("max-qps")?;
    let editing = max_concurrent.is_some()
        || max_gpus.is_some()
        || budget.is_some()
        || weight.is_some()
        || class.is_some()
        || max_qps.is_some();
    if editing {
        match ok(service.dispatch(ApiRequest::SetQuota {
            user: user.clone(),
            max_concurrent,
            max_gpus,
            gpu_second_budget: budget,
            weight,
            class,
            max_qps,
        }))? {
            ApiResponse::Ack { .. } => {
                service.platform().save_state().map_err(|e| format!("{:#}", e))?;
            }
            other => return Err(format!("unexpected reply: {:?}", other)),
        }
    }
    let views = match ok(service.dispatch(ApiRequest::TenantReport))? {
        ApiResponse::Tenants { tenants } => tenants,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    match views.iter().find(|v| v.user == user) {
        Some(v) => {
            let lim = |x: usize| if x == 0 { "unlimited".to_string() } else { format!("{}", x) };
            println!(
                "user {}: class {} weight {} | max_concurrent {} | max_gpus {} | budget {} | used {} gpu-sec | active {} | waiting {} | preempts {}",
                v.user,
                v.class,
                v.weight,
                lim(v.max_concurrent),
                lim(v.max_gpus),
                if v.gpu_second_budget > 0.0 { fnum(v.gpu_second_budget) } else { "unlimited".into() },
                fnum(v.gpu_seconds_used),
                v.active_sessions,
                v.waiting,
                v.preemptions,
            );
        }
        None => println!("user {} has the default quota (nothing recorded yet)", user),
    }
    Ok(())
}

// ---------------------------------------------------------------------
// nsml promote / endpoints — inference serving
// ---------------------------------------------------------------------

pub fn cmd_promote(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml promote", "promote a session's best checkpoint to a serving endpoint")
            .pos("endpoint", "endpoint name", true)
            .pos("session", "session to promote (required when the action is 'promote')", false)
            .opt("action", None, "promote|rollback|rollforward|retire", Some("promote")),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let endpoint = p.pos(0).unwrap().to_string();
    let action = p.get("action").unwrap_or("promote").to_string();
    let session = p.pos(1).map(str::to_string);
    let resp = ok(service.dispatch(ApiRequest::Promote {
        endpoint: endpoint.clone(),
        action: action.clone(),
        session,
    }))?;
    service.platform().save_state().map_err(|e| format!("{:#}", e))?;
    match resp {
        ApiResponse::Endpoint { endpoint: ep } => {
            println!(
                "endpoint {}: {} -> v{} (model {}, session {}, step {})",
                ep.name, action, ep.active_version, ep.model, ep.session, ep.step
            );
        }
        ApiResponse::Ack { .. } => println!("endpoint {}: retired", endpoint),
        other => return Err(format!("unexpected reply: {:?}", other)),
    }
    Ok(())
}

pub fn cmd_endpoints(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new("nsml endpoints", "list serving endpoints")).parse(args)?;
    let service = service_from(&p)?;
    let views = match ok(service.dispatch(ApiRequest::Endpoints))? {
        ApiResponse::Endpoints { endpoints } => endpoints,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    if views.is_empty() {
        println!("no endpoints yet (promote one with `nsml promote NAME SESSION`)");
        return Ok(());
    }
    let mut t = Table::new(&[
        "ENDPOINT", "ACTIVE", "MODEL", "SESSION", "STEP", "REPLICAS", "QUEUE", "P50", "P99",
        "VERSIONS",
    ])
    .right(&[1, 4, 5, 6, 7, 8, 9]);
    for v in &views {
        let q = |ms: f64| if ms > 0.0 { fms(ms) } else { "-".into() };
        t.row(&[
            v.name.clone(),
            format!("v{}", v.active_version),
            v.model.clone(),
            v.session.clone(),
            format!("{}", v.step),
            format!("{}", v.replicas),
            format!("{}", v.queue_depth),
            q(v.p50_ms),
            q(v.p99_ms),
            format!("{}", v.versions.len()),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

// ---------------------------------------------------------------------
// nsml gc — object-store sweep + durability status
// ---------------------------------------------------------------------

pub fn cmd_gc(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml gc", "sweep orphaned objects from the object store")
            .flag("status", None, "print WAL/snapshot/GC counters instead of sweeping"),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    if p.flag("status") {
        let v = match ok(service.dispatch(ApiRequest::DurabilityStatus))? {
            ApiResponse::Durability { durability } => durability,
            other => return Err(format!("unexpected reply: {:?}", other)),
        };
        if !v.enabled {
            println!("durability: off (no [durability] block or state dir)");
            return Ok(());
        }
        println!(
            "wal: {} records ({} B), last seq {} | snapshot: {}/{} records since last, {} taken (through seq {})",
            v.wal_records,
            v.wal_bytes,
            v.wal_last_seq.map(|s| s.to_string()).unwrap_or_else(|| "-".into()),
            v.records_since_snapshot,
            v.snapshot_every,
            v.snapshots,
            v.last_snapshot_seq,
        );
        println!(
            "dropped: wal {} | consumers {} | gc: {} ({} live objects, {} B; last sweep removed {} objects, {} B)",
            v.wal_dropped,
            v.consumer_dropped,
            if v.gc_enabled { "on" } else { "off" },
            v.gc_live_objects,
            v.gc_live_bytes,
            v.gc_swept_objects,
            v.gc_swept_bytes,
        );
        return Ok(());
    }
    let report = service.platform().gc().map_err(|e| format!("{:#}", e))?;
    println!(
        "gc: swept {} objects ({} B) | live {} objects ({} B)",
        report.swept_objects, report.swept_bytes, report.live_objects, report.live_bytes
    );
    if !report.per_user_bytes.is_empty() {
        let mut t = Table::new(&["USER", "CHECKPOINT BYTES"]).right(&[1]);
        for (user, bytes) in &report.per_user_bytes {
            t.row(&[user.clone(), format!("{}", bytes)]);
        }
        println!("{}", t.render());
    }
    service.platform().save_state().map_err(|e| format!("{:#}", e))?;
    Ok(())
}

// ---------------------------------------------------------------------
// nsml metrics / trace — the observability surfaces
// ---------------------------------------------------------------------

pub fn cmd_metrics(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new(
        "nsml metrics",
        "platform metrics report (counters, gauges, latency quantiles)",
    ))
    .parse(args)?;
    let service = service_from(&p)?;
    let m = match ok(service.dispatch(ApiRequest::MetricsReport))? {
        ApiResponse::Metrics { metrics } => metrics,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    if !m.enabled {
        println!("observability: off ([obs] enabled = false)");
        return Ok(());
    }
    let labels = |ls: &[(String, String)]| {
        if ls.is_empty() {
            String::new()
        } else {
            let pairs: Vec<String> = ls.iter().map(|(k, v)| format!("{}={}", k, v)).collect();
            format!("{{{}}}", pairs.join(","))
        }
    };
    if m.counters.is_empty() && m.gauges.is_empty() && m.histograms.is_empty() {
        println!("no metrics recorded yet (drive or dispatch something first)");
        return Ok(());
    }
    if !m.counters.is_empty() || !m.gauges.is_empty() {
        let mut t = Table::new(&["METRIC", "VALUE"]).right(&[1]);
        for c in &m.counters {
            t.row(&[format!("{}{}", c.name, labels(&c.labels)), fnum(c.value)]);
        }
        for g in &m.gauges {
            t.row(&[format!("{}{}", g.name, labels(&g.labels)), fnum(g.value)]);
        }
        println!("{}", t.render());
    }
    if !m.histograms.is_empty() {
        let mut t = Table::new(&["HISTOGRAM", "COUNT", "P50", "P95", "P99"]).right(&[1, 2, 3, 4]);
        for h in &m.histograms {
            t.row(&[
                format!("{}{}", h.name, labels(&h.labels)),
                format!("{}", h.count),
                fms(h.p50_ms),
                fms(h.p95_ms),
                fms(h.p99_ms),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

pub fn cmd_trace(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml trace", "spans recorded under a trace id")
            .pos("trace", "trace id (the X-Trace-Id header / dispatch trace)", true),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let id = p.pos(0).unwrap().to_string();
    let view = match ok(service.dispatch(ApiRequest::Trace { id }))? {
        ApiResponse::Trace { trace } => trace,
        other => return Err(format!("unexpected reply: {:?}", other)),
    };
    println!("trace {} — {} spans", view.id, view.spans.len());
    let mut t = Table::new(&["AT(ms)", "DUR", "SPAN", "SOURCE", "DETAIL"]).right(&[0, 1]);
    for sp in &view.spans {
        t.row(&[
            format!("{}", sp.at_ms),
            fms(sp.dur_ms),
            sp.name.clone(),
            sp.source.clone(),
            sp.detail.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

pub fn cmd_models(args: &[String]) -> CmdResult {
    let p = with_globals(ArgSpec::new("nsml models", "list AOT-compiled models")).parse(args)?;
    let platform = platform_from(&p)?;
    let mut t = Table::new(&["MODEL", "DATASET", "PARAMS", "BATCH", "METRIC", "DESCRIPTION"]).right(&[2, 3]);
    for name in platform.engine().manifest().model_names() {
        let m = platform.engine().manifest().model(&name).unwrap();
        t.row(&[
            name.clone(),
            crate::data::dataset_for(&name).to_string(),
            format!("{}", m.param_count),
            format!("{}", m.batch),
            format!("{}{}", m.metric_name, if m.lower_is_better { " ↓" } else { " ↑" }),
            m.description.clone(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

pub fn cmd_web(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml web", "serve the web UI over the state directory")
            .opt("port", Some('p'), "port (0 = ephemeral)", Some("8080"))
            .flag("once", None, "bind, print the URL, and exit (for tests)"),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let (api, rx) = crate::api::service_channel();
    let platform = service.platform();
    let state = crate::web::WebState {
        sessions: platform.sessions.clone(),
        leaderboard: platform.leaderboard.clone(),
        cluster: Some(platform.cluster.clone()),
        events: platform.events.clone(),
        api: Some(api),
        obs: Some(platform.obs.clone()),
    };
    let port: u16 = p.get_usize("port")? as u16;
    let srv = crate::web::serve(state, port).map_err(|e| e.to_string())?;
    println!("nsml web ui: http://127.0.0.1:{}/  (mutations: POST /api/v1/<verb>)", srv.port());
    if p.flag("once") {
        srv.shutdown();
        return Ok(());
    }
    // This thread owns the platform; pump web dispatches through the
    // service until the process exits.
    service.serve(&rx);
    Ok(())
}

// ---------------------------------------------------------------------
// nsml serve — always-on service mode
// ---------------------------------------------------------------------

/// Daemon mode: the pooled HTTP front end answers reads, SSE streams,
/// and mutations while this thread — the platform owner — continuously
/// runs drive rounds, answering dispatches between rounds.
pub fn cmd_serve(args: &[String]) -> CmdResult {
    let p = with_globals(
        ArgSpec::new("nsml serve", "run the platform as a service: HTTP front end + drive loop")
            .opt("port", Some('p'), "port (0 = ephemeral)", Some("8080"))
            .opt("rounds", None, "exit after this many drive rounds (0 = serve forever)", Some("0"))
            .opt("for-ms", None, "stop cleanly after this many wall-clock ms (0 = no deadline)", Some("0")),
    )
    .parse(args)?;
    let service = service_from(&p)?;
    let (api, rx) = crate::api::service_channel();
    let platform = service.platform();
    let state = crate::web::WebState {
        sessions: platform.sessions.clone(),
        leaderboard: platform.leaderboard.clone(),
        cluster: Some(platform.cluster.clone()),
        events: platform.events.clone(),
        api: Some(api),
        obs: Some(platform.obs.clone()),
    };
    let cfg = &platform.config;
    let opts = crate::web::ServeOpts {
        workers: cfg.http_workers,
        keepalive: std::time::Duration::from_millis(cfg.http_keepalive_ms),
        ..crate::web::ServeOpts::default()
    };
    let daemon = DaemonOpts {
        chunk: cfg.serve_chunk,
        max_rounds: p.get_usize("rounds")? as u64,
        idle_wait: std::time::Duration::from_millis(cfg.serve_idle_ms),
        ..DaemonOpts::default()
    };

    let port: u16 = p.get_usize("port")? as u16;
    let srv = crate::web::serve_with(state, port, opts).map_err(|e| e.to_string())?;
    println!(
        "nsml service: http://127.0.0.1:{}/  (drive loop on; SSE: GET /api/v1/events/stream)",
        srv.port()
    );

    // Optional wall-clock deadline, so scripted smoke runs (and anything
    // without a supervisor) can get a clean, state-saving shutdown.
    let deadline_ms = p.get_usize("for-ms")? as u64;
    if deadline_ms > 0 {
        let stop = daemon.stop.clone();
        std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(deadline_ms));
            stop.store(true, std::sync::atomic::Ordering::SeqCst);
        });
    }

    // This thread owns the platform: run the drive loop, answering web
    // dispatches between rounds, until a stop condition fires.
    service.run_daemon(&rx, &daemon).map_err(|e| format!("{:#}", e))?;
    srv.shutdown();
    Ok(())
}

/// Bench/report helper: how long operations took, from the virtual clock.
#[allow(dead_code)]
pub fn fmt_virtual(ms: u64) -> String {
    fms(ms as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(args: &[&str]) -> Vec<String> {
        args.iter().map(|a| a.to_string()).collect()
    }

    fn artifacts_ok() -> bool {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json").exists()
    }

    fn tmp_state(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("nsml-cli-{}-{}", tag, std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_string_lossy().to_string()
    }

    #[test]
    fn help_paths() {
        assert_eq!(crate::cli::main(&s(&["help"])), 0);
        assert_eq!(crate::cli::main(&s(&[])), 0);
        assert_eq!(crate::cli::main(&s(&["frobnicate"])), 1);
    }

    #[test]
    fn dataset_ls_and_models() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("ls");
        assert_eq!(crate::cli::main(&s(&["dataset", "ls", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["models", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["cluster", "--state", &state])), 0);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn run_then_ps_then_board_compose_via_state() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("run");
        assert_eq!(
            crate::cli::main(&s(&[
                "run", "main.py", "-d", "mnist", "--steps", "30", "--quiet", "--state", &state
            ])),
            0
        );
        assert_eq!(crate::cli::main(&s(&["ps", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["dataset", "board", "mnist", "--state", &state])), 0);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn control_verbs_dispatch_through_service() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("ctl");
        assert_eq!(
            crate::cli::main(&s(&[
                "run", "main.py", "-d", "mnist", "--steps", "20", "--quiet", "--state", &state
            ])),
            0
        );
        // Recover the session id from the persisted state.
        let text = std::fs::read_to_string(PathBuf::from(&state).join("state.json")).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        let id = doc
            .get("sessions")
            .and_then(|s| s.as_arr())
            .and_then(|a| a.first())
            .and_then(|r| r.at(&["spec", "id"]))
            .and_then(|j| j.as_str())
            .expect("session id in state")
            .to_string();
        // Stop acks even on a finished session (idempotent terminal path).
        assert_eq!(crate::cli::main(&s(&["stop", &id, "--state", &state])), 0);
        // Pause on a non-active session is a failed precondition.
        assert_eq!(crate::cli::main(&s(&["pause", &id, "--state", &state])), 1);
        // Unknown sessions map to not_found.
        assert_eq!(crate::cli::main(&s(&["stop", "missing", "--state", &state])), 1);
        assert_eq!(crate::cli::main(&s(&["resume", "missing", "--state", &state])), 1);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn logs_follow_drives_a_resumed_session_to_done() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("logsf");
        assert_eq!(
            crate::cli::main(&s(&[
                "run", "main.py", "-d", "mnist", "--steps", "20", "--quiet", "--state", &state
            ])),
            0
        );
        let text = std::fs::read_to_string(PathBuf::from(&state).join("state.json")).unwrap();
        let doc = crate::util::json::parse(&text).unwrap();
        let id = doc
            .get("sessions")
            .and_then(|s| s.as_arr())
            .and_then(|a| a.first())
            .and_then(|r| r.at(&["spec", "id"]))
            .and_then(|j| j.as_str())
            .expect("session id in state")
            .to_string();
        // Plain logs on a finished session prints history and exits 0.
        assert_eq!(crate::cli::main(&s(&["logs", &id, "--state", &state])), 0);
        // Follow mode on a terminal session is a no-op that still exits 0.
        assert_eq!(crate::cli::main(&s(&["logs", &id, "-f", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["logs", "missing", "--state", &state])), 1);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn quota_and_tenants_compose_via_state() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("quota");
        // Empty platform: tenants prints the no-tenants hint, quota
        // reports the default for an unknown user.
        assert_eq!(crate::cli::main(&s(&["tenants", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["quota", "ghost", "--state", &state])), 0);
        // Set a quota; it persists into the state dir and the next
        // invocation (a fresh platform) still sees it.
        assert_eq!(
            crate::cli::main(&s(&[
                "quota", "kim", "--max-gpus", "4", "--weight", "2", "--class", "high", "--state",
                &state
            ])),
            0
        );
        assert_eq!(crate::cli::main(&s(&["quota", "kim", "--state", &state])), 0);
        let text = std::fs::read_to_string(PathBuf::from(&state).join("state.json")).unwrap();
        assert!(text.contains("\"max_gpus\": 4") || text.contains("\"max_gpus\":4"), "{}", text);
        // Bad inputs fail cleanly.
        assert_eq!(crate::cli::main(&s(&["quota", "kim", "--weight", "heavy", "--state", &state])), 1);
        assert_eq!(
            crate::cli::main(&s(&["quota", "kim", "--class", "frobnicate", "--state", &state])),
            1
        );
        assert_eq!(crate::cli::main(&s(&["tenants", "--state", &state])), 0);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn gc_sweeps_and_reports_status() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("gc");
        // GC on an empty store is a no-op that still exits 0.
        assert_eq!(crate::cli::main(&s(&["gc", "--state", &state])), 0);
        assert_eq!(
            crate::cli::main(&s(&[
                "run", "main.py", "-d", "mnist", "--steps", "20", "--quiet", "--state", &state
            ])),
            0
        );
        // A fresh invocation recovers the state dir, sweeps, and can
        // report the durability counters.
        assert_eq!(crate::cli::main(&s(&["gc", "--state", &state])), 0);
        assert_eq!(crate::cli::main(&s(&["gc", "--status", "--state", &state])), 0);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn serve_bounded_exits_cleanly() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("serve");
        // Bounded rounds on an idle platform: the daemon notices there is
        // nothing to drive and exits once the budget applies.
        assert_eq!(
            crate::cli::main(&s(&["serve", "--port", "0", "--rounds", "3", "--state", &state])),
            0
        );
        // A wall-clock deadline stops an unbounded loop cleanly too.
        assert_eq!(
            crate::cli::main(&s(&["serve", "--port", "0", "--for-ms", "60", "--state", &state])),
            0
        );
        // Clean shutdown saved state (the dir exists even with no sessions).
        assert!(PathBuf::from(&state).join("state.json").exists());
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn metrics_and_trace_commands() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("metrics");
        // A fresh platform has nothing recorded yet but still exits 0.
        assert_eq!(crate::cli::main(&s(&["metrics", "--state", &state])), 0);
        // An unknown trace id maps to not_found -> exit 1.
        assert_eq!(crate::cli::main(&s(&["trace", "never-minted", "--state", &state])), 1);
        let _ = std::fs::remove_dir_all(&state);
    }

    #[test]
    fn run_missing_dataset_fails() {
        if !artifacts_ok() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let state = tmp_state("miss");
        assert_eq!(crate::cli::main(&s(&["run", "main.py", "--state", &state])), 1);
        assert_eq!(crate::cli::main(&s(&["run", "m.py", "-d", "nope", "--state", &state])), 1);
        let _ = std::fs::remove_dir_all(&state);
    }
}
