//! Plain-text table rendering for the CLI (leaderboards, `nsml ps`, …).

/// A simple text table builder with column alignment.
#[derive(Debug, Clone, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    right_align: Vec<bool>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            right_align: vec![false; headers.len()],
        }
    }

    /// Right-align the given column indexes (numbers usually).
    pub fn right(mut self, cols: &[usize]) -> Self {
        for &c in cols {
            if c < self.right_align.len() {
                self.right_align[c] = true;
            }
        }
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        let mut v = cells.to_vec();
        v.resize(self.headers.len(), String::new());
        self.rows.push(v);
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Render with a header separator, space-padded columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let fmt_row = |cells: &[String], out: &mut String| {
            for (i, cell) in cells.iter().enumerate().take(ncols) {
                if i > 0 {
                    out.push_str("  ");
                }
                let pad = widths[i].saturating_sub(cell.chars().count());
                if self.right_align[i] {
                    out.push_str(&" ".repeat(pad));
                    out.push_str(cell);
                } else {
                    out.push_str(cell);
                    if i + 1 < ncols {
                        out.push_str(&" ".repeat(pad));
                    }
                }
            }
            out.push('\n');
        };
        let mut out = String::new();
        fmt_row(&self.headers, &mut out);
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out
    }
}

/// Format a float compactly for tables (4 significant-ish digits).
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{:.0}", x)
    } else if x.abs() >= 10.0 {
        format!("{:.2}", x)
    } else if x.abs() >= 0.01 {
        format!("{:.4}", x)
    } else {
        format!("{:.3e}", x)
    }
}

/// Format milliseconds human-readably.
pub fn fms(ms: f64) -> String {
    if ms < 1.0 {
        format!("{:.0}µs", ms * 1000.0)
    } else if ms < 1000.0 {
        format!("{:.2}ms", ms)
    } else if ms < 60_000.0 {
        format!("{:.2}s", ms / 1000.0)
    } else {
        format!("{:.1}min", ms / 60_000.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["NAME", "SCORE"]).right(&[1]);
        t.row_strs(&["alpha", "1.0"]);
        t.row_strs(&["a-much-longer-name", "12.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("NAME"));
        assert!(lines[2].ends_with(" 1.0"));
        assert!(lines[3].ends_with("12.5"));
    }

    #[test]
    fn pads_short_rows() {
        let mut t = Table::new(&["A", "B", "C"]);
        t.row_strs(&["x"]);
        assert!(t.render().contains('x'));
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(1234.0), "1234");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(0.5), "0.5000");
        assert_eq!(fnum(0.0001), "1.000e-4");
    }

    #[test]
    fn fms_ranges() {
        assert_eq!(fms(0.5), "500µs");
        assert_eq!(fms(12.0), "12.00ms");
        assert_eq!(fms(2500.0), "2.50s");
        assert_eq!(fms(120_000.0), "2.0min");
    }
}
