//! Mini-criterion: a benchmark harness for `harness = false` benches.
//!
//! Provides warmup, adaptive iteration counts, summary statistics,
//! pairwise comparison ("A is 3.2× faster than B"), and a machine-readable
//! JSON dump alongside the human-readable report.

use super::json::Json;
use super::stats;
use super::table::{fms, Table};
use std::time::Instant;

/// True when the process runs in smoke mode (`BENCH_SMOKE=1` or a
/// `--smoke` argv flag): CI builds every bench and executes it with a
/// tiny iteration count purely to catch bit-rot. Benches should use
/// this to shrink their workloads (fewer sessions, fewer steps) and to
/// skip performance assertions that only hold at full scale.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke")
}

/// One measured benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ms: Vec<f64>,
    /// Optional units processed per iteration (for throughput reporting).
    pub units_per_iter: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        stats(&self.samples_ms).mean
    }

    pub fn p50_ms(&self) -> f64 {
        stats(&self.samples_ms).p50
    }

    pub fn p99_ms(&self) -> f64 {
        stats(&self.samples_ms).p99
    }

    /// Units per second, if units were declared.
    pub fn throughput(&self) -> Option<f64> {
        self.units_per_iter.map(|u| u / (self.mean_ms() / 1000.0))
    }
}

/// One recorded perf-gate verdict (an assertion the full-scale bench
/// enforces, carried into the JSON dump so CI artifacts show *which*
/// gate tripped, not just that the process died).
#[derive(Debug, Clone)]
pub struct GateResult {
    pub name: String,
    pub pass: bool,
    /// The measured values behind the verdict, human-readable.
    pub detail: String,
}

/// Benchmark runner: collects results, prints a report.
pub struct Bench {
    suite: String,
    warmup_iters: usize,
    sample_count: usize,
    results: Vec<BenchResult>,
    gates: Vec<GateResult>,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        // NSML_BENCH_FAST=1 shrinks sampling; BENCH_SMOKE / --smoke
        // shrinks harder (the CI bit-rot gate runs 1 warmup + 2 samples).
        let fast = std::env::var("NSML_BENCH_FAST").is_ok();
        let smoke = smoke();
        Bench {
            suite: suite.to_string(),
            warmup_iters: if fast || smoke { 1 } else { 3 },
            sample_count: if smoke {
                2
            } else if fast {
                5
            } else {
                15
            },
            results: Vec::new(),
            gates: Vec::new(),
        }
    }

    pub fn with_samples(mut self, n: usize) -> Self {
        self.sample_count = n;
        self
    }

    /// Measure `f` (one call = one iteration).
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.run_units(name, None, &mut f)
    }

    /// Measure `f` that processes `units` items per call.
    pub fn run_with_units<F: FnMut()>(&mut self, name: &str, units: f64, mut f: F) -> &BenchResult {
        self.run_units(name, Some(units), &mut f)
    }

    fn run_units(&mut self, name: &str, units: Option<f64>, f: &mut dyn FnMut()) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.sample_count);
        for _ in 0..self.sample_count {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1000.0);
        }
        eprintln!("  measured {:<44} p50={}", name, fms(stats(&samples).p50));
        self.results.push(BenchResult {
            name: name.to_string(),
            samples_ms: samples,
            units_per_iter: units,
        });
        self.results.last().unwrap()
    }

    /// Record an externally measured sample set (for virtual-time benches).
    pub fn record(&mut self, name: &str, samples_ms: Vec<f64>, units: Option<f64>) {
        self.results.push(BenchResult { name: name.to_string(), samples_ms, units_per_iter: units });
    }

    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// Record a perf-gate verdict and return `pass` unchanged, so
    /// benches can write `let ok = b.gate(...); ...; assert!(ok)` —
    /// record first, save the JSON, *then* panic, and the artifact
    /// still carries the failing gate.
    pub fn gate(&mut self, name: &str, pass: bool, detail: &str) -> bool {
        eprintln!("  gate {:<47} {} ({})", name, if pass { "PASS" } else { "FAIL" }, detail);
        self.gates.push(GateResult { name: name.to_string(), pass, detail: detail.to_string() });
        pass
    }

    /// All recorded gates passed (vacuously true with none recorded).
    pub fn gates_pass(&self) -> bool {
        self.gates.iter().all(|g| g.pass)
    }

    /// Print the human-readable report; returns it as a string too.
    pub fn report(&self) -> String {
        let mut t = Table::new(&["benchmark", "p50", "mean", "p95", "std", "throughput"]).right(&[1, 2, 3, 4, 5]);
        for r in &self.results {
            let s = stats(&r.samples_ms);
            let tp = match r.throughput() {
                Some(x) if x >= 1000.0 => format!("{:.0}/s", x),
                Some(x) => format!("{:.2}/s", x),
                None => "-".to_string(),
            };
            t.row(&[
                r.name.clone(),
                fms(s.p50),
                fms(s.mean),
                fms(s.p95),
                fms(s.std),
                tp,
            ]);
        }
        let mut out = format!("\n== {} ==\n{}", self.suite, t.render());
        for line in self.comparisons() {
            out.push_str(&line);
            out.push('\n');
        }
        println!("{}", out);
        out
    }

    /// Pairwise speedups vs the first result (the baseline).
    fn comparisons(&self) -> Vec<String> {
        let mut lines = Vec::new();
        if let Some(base) = self.results.first() {
            let b = base.mean_ms();
            for r in &self.results[1..] {
                let ratio = b / r.mean_ms();
                if ratio >= 1.0 {
                    lines.push(format!("  {} is {:.2}x faster than {}", r.name, ratio, base.name));
                } else {
                    lines.push(format!("  {} is {:.2}x slower than {}", r.name, 1.0 / ratio, base.name));
                }
            }
        }
        lines
    }

    /// Dump machine-readable results (ops/sec, p50/p99, gate verdicts)
    /// to `target/bench-results/BENCH_<suite>.json` — the artifact
    /// `scripts/bench_smoke.sh` collects so the perf trajectory is
    /// recorded across PRs.
    pub fn save_json(&self) {
        let mut arr = Vec::new();
        for r in &self.results {
            let s = stats(&r.samples_ms);
            let mut o = Json::obj();
            o.set("name", r.name.as_str().into())
                .set("mean_ms", s.mean.into())
                .set("p50_ms", s.p50.into())
                .set("p95_ms", s.p95.into())
                .set("p99_ms", s.p99.into())
                .set("std_ms", s.std.into())
                .set("samples", (s.n as u64).into());
            if let Some(tp) = r.throughput() {
                o.set("ops_per_s", tp.into());
            }
            arr.push(o);
        }
        let mut gates = Vec::new();
        for g in &self.gates {
            let mut o = Json::obj();
            o.set("name", g.name.as_str().into())
                .set("pass", g.pass.into())
                .set("detail", g.detail.as_str().into());
            gates.push(o);
        }
        let mut doc = Json::obj();
        doc.set("suite", self.suite.as_str().into())
            .set("smoke", smoke().into())
            .set("pass", self.gates_pass().into())
            .set("results", Json::Arr(arr))
            .set("gates", Json::Arr(gates));
        let dir = std::path::Path::new("target/bench-results");
        let _ = std::fs::create_dir_all(dir);
        let path = dir.join(format!("BENCH_{}.json", self.suite.replace([' ', '/'], "_")));
        let _ = std::fs::write(path, doc.to_pretty());
    }

    /// `report()` + `save_json()`.
    pub fn finish(&self) {
        self.report();
        self.save_json();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        std::env::set_var("NSML_BENCH_FAST", "1");
        let mut b = Bench::new("unit-test-suite").with_samples(3);
        b.run("noop", || {});
        b.run_with_units("spin", 100.0, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        let rep = b.report();
        assert!(rep.contains("noop"));
        assert!(rep.contains("spin"));
        assert!(b.result("spin").unwrap().throughput().unwrap() > 0.0);
        assert!(rep.contains("faster") || rep.contains("slower"));
    }

    #[test]
    fn smoke_mode_shrinks_sampling() {
        std::env::set_var("BENCH_SMOKE", "1");
        assert!(smoke());
        let b = Bench::new("smoke-suite");
        assert_eq!(b.sample_count, 2);
        assert_eq!(b.warmup_iters, 1);
        std::env::set_var("BENCH_SMOKE", "0");
        assert!(!smoke());
        std::env::remove_var("BENCH_SMOKE");
    }

    #[test]
    fn gates_record_and_return_their_verdict() {
        let mut b = Bench::new("gate-suite");
        b.record("x", vec![1.0], None);
        assert!(b.gates_pass(), "no gates recorded yet");
        assert!(b.gate("fast_enough", true, "p99 1ms <= 2ms"));
        assert!(!b.gate("scaled_up", false, "peak replicas 1 < 2"));
        assert!(!b.gates_pass());
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::new("virtual");
        b.record("simulated", vec![1.0, 2.0, 3.0], Some(10.0));
        let r = b.result("simulated").unwrap();
        assert!((r.mean_ms() - 2.0).abs() < 1e-9);
        assert!((r.throughput().unwrap() - 5000.0).abs() < 1e-6);
    }
}
