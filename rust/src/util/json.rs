//! Minimal JSON parser and serializer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), the web
//! API, and on-disk persistence of platform state. Implements the full JSON
//! grammar (RFC 8259) minus exotic number forms; numbers are stored as `f64`
//! (integers round-trip exactly up to 2^53, more than enough here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["a", "b"])` == `j["a"]["b"]`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&"  ".repeat(indent + 1));
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 9e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{}", n));
    } else {
        // JSON has no Inf/NaN; emit null like most serializers in lenient mode.
        out.push_str("null");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error with byte offset on failure.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected character at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{}': {}", s, e))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // Surrogate pairs: peek for a low surrogate.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos + 5..].starts_with(b"\\u") && self.pos + 11 <= self.bytes.len() {
                                    let hex2 = std::str::from_utf8(&self.bytes[self.pos + 7..self.pos + 11])
                                        .map_err(|_| "bad surrogate")?;
                                    let lo = u32::from_str_radix(hex2, 16).map_err(|_| "bad surrogate")?;
                                    self.pos += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or("bad surrogate pair")?
                                } else {
                                    return Err("lone high surrogate".into());
                                }
                            } else {
                                char::from_u32(cp).ok_or("bad codepoint")?
                            };
                            out.push(ch);
                            self.pos += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).map_err(|_| "invalid utf-8")?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "1e3", "\"hi\""] {
            let v = parse(src).unwrap();
            let back = parse(&v.to_string()).unwrap();
            assert_eq!(v, back, "roundtrip {}", src);
        }
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["c"]), Some(&Json::Null));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\nb\t\"c\" \\ A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"c\" \\ A 😀");
        // And the serializer escapes back safely.
        let out = v.to_string();
        let back = parse(&out).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_exact() {
        let v = parse("9007199254740991").unwrap();
        assert_eq!(v.to_string(), "9007199254740991");
        assert_eq!(v.as_i64(), Some(9007199254740991));
    }

    #[test]
    fn builder_api() {
        let mut o = Json::obj();
        o.set("name", "mnist".into()).set("gpus", 2usize.into()).set("ok", true.into());
        assert_eq!(o.to_string(), r#"{"gpus":2,"name":"mnist","ok":true}"#);
    }

    #[test]
    fn pretty_parses_back() {
        let v = parse(r#"{"a":[1,2],"b":{"c":true}}"#).unwrap();
        assert_eq!(parse(&v.to_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"한국어 テスト\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "한국어 テスト");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }
}
