//! Deterministic PRNG: PCG64 (O'Neill's PCG XSL-RR 128/64).
//!
//! Used everywhere the platform needs randomness — synthetic data
//! generation, AutoML search, simulated latencies, property tests — so
//! every run is reproducible from a seed, matching NSML's "reproduce past
//! experiments" requirement.

/// PCG XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    /// Create from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Rng {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    /// Create with an explicit stream id; distinct streams are independent.
    pub fn with_stream(seed: u64, stream: u64) -> Rng {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Rng { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::with_stream(self.next_u64() ^ tag, tag.wrapping_mul(2654435769) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn gauss(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an exponential with the given mean (for simulated latencies).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Vector of standard normals as f32 (parameter init, synthetic data).
    pub fn normal_vec_f32(&mut self, n: usize, mean: f32, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.gauss(mean as f64, std as f64) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_coverage() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {} out of range", c);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {}", mean);
        assert!((var - 1.0).abs() < 0.05, "var {}", var);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(9);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..50_000).map(|_| r.exponential(5.0)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 5.0).abs() < 0.2, "mean {}", mean);
    }
}
