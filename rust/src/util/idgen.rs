//! Human-friendly unique id generation.
//!
//! NSML sessions get kaggle/nsml-style ids like `nsml/mnist/7-brave-hornet`;
//! this module provides the monotonic counter + name mangle.

use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(1);

const ADJ: &[&str] = &[
    "brave", "calm", "deft", "eager", "fuzzy", "grand", "happy", "ideal", "jolly", "keen",
    "lucid", "merry", "noble", "prime", "quick", "rapid", "sharp", "tidy", "vivid", "witty",
];
const NOUN: &[&str] = &[
    "ant", "bear", "crane", "dove", "eagle", "fox", "gull", "hornet", "ibis", "jay",
    "koala", "lynx", "mole", "newt", "otter", "panda", "quail", "raven", "seal", "tiger",
];

/// Next global sequence number (process-wide, monotone).
pub fn next_seq() -> u64 {
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

/// Reset the counter (tests only).
pub fn reset_for_test() {
    COUNTER.store(1, Ordering::SeqCst);
}

/// A readable session suffix like `7-brave-hornet`, deterministic in `seq`.
pub fn session_suffix(seq: u64) -> String {
    let a = ADJ[(seq.wrapping_mul(2654435761) % ADJ.len() as u64) as usize];
    let n = NOUN[(seq.wrapping_mul(40503) % NOUN.len() as u64) as usize];
    format!("{}-{}-{}", seq, a, n)
}

/// Full session id: `user/dataset/seq-adj-noun` (paper's SESSION handle).
pub fn session_id(user: &str, dataset: &str) -> String {
    format!("{}/{}/{}", user, dataset, session_suffix(next_seq()))
}

/// Sanitize a string for use as a filesystem path component.
pub fn sanitize(s: &str) -> String {
    s.chars().map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seq_monotone() {
        let a = next_seq();
        let b = next_seq();
        assert!(b > a);
    }

    #[test]
    fn suffix_deterministic() {
        assert_eq!(session_suffix(7), session_suffix(7));
        assert_ne!(session_suffix(7), session_suffix(8));
        assert!(session_suffix(3).starts_with("3-"));
    }

    #[test]
    fn session_id_shape() {
        let id = session_id("kim", "mnist");
        let parts: Vec<&str> = id.split('/').collect();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0], "kim");
        assert_eq!(parts[1], "mnist");
    }

    #[test]
    fn sanitize_paths() {
        assert_eq!(sanitize("kim/mnist/1-a-b"), "kim_mnist_1-a-b");
        assert_eq!(sanitize("ok-file_1.txt"), "ok-file_1.txt");
    }
}
