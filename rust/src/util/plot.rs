//! Learning-curve plotting: ASCII charts for the CLI (`nsml plot`) and SVG
//! charts for the web UI — the platform's TensorBoard/Visdom stand-in.

/// A named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str, points: Vec<(f64, f64)>) -> Series {
        Series { name: name.to_string(), points }
    }

    pub fn from_ys(name: &str, ys: &[f64]) -> Series {
        Series::new(name, ys.iter().enumerate().map(|(i, &y)| (i as f64, y)).collect())
    }
}

fn bounds(series: &[Series]) -> Option<(f64, f64, f64, f64)> {
    let mut it = series.iter().flat_map(|s| s.points.iter()).copied();
    let (x0, y0) = it.next()?;
    let mut b = (x0, x0, y0, y0);
    for (x, y) in it {
        b.0 = b.0.min(x);
        b.1 = b.1.max(x);
        b.2 = b.2.min(y);
        b.3 = b.3.max(y);
    }
    // Avoid zero-size ranges.
    if b.0 == b.1 {
        b.1 += 1.0;
    }
    if b.2 == b.3 {
        b.3 += 1.0;
    }
    Some(b)
}

const MARKS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

/// Render an ASCII line chart (scatter of the series points on a grid).
pub fn ascii_chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let Some((xmin, xmax, ymin, ymax)) = bounds(series) else {
        return format!("{}\n(no data)\n", title);
    };
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let cx = ((x - xmin) / (xmax - xmin) * (width - 1) as f64).round() as usize;
            let cy = ((y - ymin) / (ymax - ymin) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(title);
    out.push('\n');
    out.push_str(&format!("{:>10.4} ┤", ymax));
    out.push_str(&grid[0].iter().collect::<String>());
    out.push('\n');
    for row in &grid[1..height - 1] {
        out.push_str("           │");
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>10.4} ┤", ymin));
    out.push_str(&grid[height - 1].iter().collect::<String>());
    out.push('\n');
    out.push_str(&format!("           └{}\n", "─".repeat(width)));
    out.push_str(&format!("            {:<10.4}{:>w$.4}\n", xmin, xmax, w = width.saturating_sub(10)));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("            {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

/// Render an SVG line chart (for the web UI).
pub fn svg_chart(title: &str, series: &[Series], width: usize, height: usize) -> String {
    let colors = ["#1f77b4", "#ff7f0e", "#2ca02c", "#d62728", "#9467bd", "#8c564b"];
    let (w, h) = (width as f64, height as f64);
    let (ml, mr, mt, mb) = (56.0, 12.0, 28.0, 34.0); // margins
    let mut svg = format!(
        r#"<svg xmlns="http://www.w3.org/2000/svg" width="{width}" height="{height}" font-family="monospace" font-size="11">"#
    );
    svg.push_str(&format!(
        r##"<rect width="{width}" height="{height}" fill="white" stroke="#ccc"/>"##
    ));
    svg.push_str(&format!(
        r#"<text x="{}" y="18" font-size="13" text-anchor="middle">{}</text>"#,
        w / 2.0,
        xml_escape(title)
    ));
    let Some((xmin, xmax, ymin, ymax)) = bounds(series) else {
        svg.push_str("</svg>");
        return svg;
    };
    let px = |x: f64| ml + (x - xmin) / (xmax - xmin) * (w - ml - mr);
    let py = |y: f64| h - mb - (y - ymin) / (ymax - ymin) * (h - mt - mb);
    // Axes + gridlines with labels.
    for i in 0..=4 {
        let frac = i as f64 / 4.0;
        let yv = ymin + frac * (ymax - ymin);
        let ypix = py(yv);
        svg.push_str(&format!(
            r##"<line x1="{ml}" y1="{ypix:.1}" x2="{:.1}" y2="{ypix:.1}" stroke="#eee"/>"##,
            w - mr
        ));
        svg.push_str(&format!(
            r#"<text x="{:.1}" y="{:.1}" text-anchor="end">{}</text>"#,
            ml - 4.0,
            ypix + 4.0,
            short(yv)
        ));
        let xv = xmin + frac * (xmax - xmin);
        let xpix = px(xv);
        svg.push_str(&format!(
            r#"<text x="{:.1}" y="{:.1}" text-anchor="middle">{}</text>"#,
            xpix,
            h - mb + 16.0,
            short(xv)
        ));
    }
    svg.push_str(&format!(
        r##"<line x1="{ml}" y1="{:.1}" x2="{:.1}" y2="{:.1}" stroke="#333"/>"##,
        h - mb,
        w - mr,
        h - mb
    ));
    svg.push_str(&format!(r##"<line x1="{ml}" y1="{mt}" x2="{ml}" y2="{:.1}" stroke="#333"/>"##, h - mb));
    for (si, s) in series.iter().enumerate() {
        if s.points.is_empty() {
            continue;
        }
        let color = colors[si % colors.len()];
        let path: Vec<String> = s
            .points
            .iter()
            .enumerate()
            .map(|(i, &(x, y))| {
                format!("{}{:.1},{:.1}", if i == 0 { "M" } else { "L" }, px(x), py(y))
            })
            .collect();
        svg.push_str(&format!(
            r#"<path d="{}" fill="none" stroke="{}" stroke-width="1.5"/>"#,
            path.join(" "),
            color
        ));
        svg.push_str(&format!(
            r#"<text x="{:.1}" y="{:.1}" fill="{}">{}</text>"#,
            ml + 8.0,
            mt + 14.0 * (si as f64 + 1.0),
            color,
            xml_escape(&s.name)
        ));
    }
    svg.push_str("</svg>");
    svg
}

fn short(v: f64) -> String {
    if v == 0.0 {
        "0".into()
    } else if v.abs() >= 10000.0 || v.abs() < 0.001 {
        format!("{:.1e}", v)
    } else if v == v.trunc() {
        format!("{}", v as i64)
    } else {
        format!("{:.3}", v)
    }
}

/// Escape text for embedding in XML/HTML.
pub fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;").replace('"', "&quot;")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_contains_marks_and_legend() {
        let s = Series::from_ys("loss", &[5.0, 3.0, 2.0, 1.5, 1.2, 1.1]);
        let out = ascii_chart("training", &[s], 40, 10);
        assert!(out.contains('*'));
        assert!(out.contains("loss"));
        assert!(out.contains("training"));
        assert!(out.lines().count() > 10);
    }

    #[test]
    fn ascii_empty_ok() {
        let out = ascii_chart("t", &[], 40, 10);
        assert!(out.contains("no data"));
    }

    #[test]
    fn ascii_constant_series_ok() {
        let s = Series::from_ys("flat", &[1.0, 1.0, 1.0]);
        let out = ascii_chart("t", &[s], 20, 5);
        assert!(out.contains('*'));
    }

    #[test]
    fn svg_well_formed_enough() {
        let a = Series::from_ys("train", &[3.0, 2.0, 1.0]);
        let b = Series::from_ys("val", &[3.5, 2.5, 1.8]);
        let svg = svg_chart("loss", &[a, b], 480, 280);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<path").count(), 2);
        assert!(svg.contains("train") && svg.contains("val"));
    }

    #[test]
    fn escape_works() {
        assert_eq!(xml_escape("a<b>&\"c\""), "a&lt;b&gt;&amp;&quot;c&quot;");
    }
}
