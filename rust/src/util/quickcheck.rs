//! Minimal property-testing harness (proptest stand-in).
//!
//! `forall(seed, cases, gen, prop)` runs `prop` on `cases` random inputs
//! drawn by `gen`. On failure it performs greedy shrinking via the
//! [`Shrink`] trait and panics with the seed + minimal counterexample so
//! the failure is reproducible.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller values, in decreasing aggressiveness.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self > 0 {
            v.push(0);
            v.push(self / 2);
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        (*self as u64).shrink().into_iter().map(|x| x as usize).collect()
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut v = Vec::new();
        if *self != 0.0 {
            v.push(0.0);
            v.push(self / 2.0);
            v.push(self.trunc());
        }
        v.retain(|x| x != self);
        v
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Halve, drop one element, shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        for i in 0..self.len().min(4) {
            for cand in self[i].shrink() {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self.0.shrink().into_iter().map(|a| (a, self.1.clone())).collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs; shrink + panic on failure.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing candidate.
            let mut cur = input;
            let mut cur_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in cur.shrink() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        cur_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={}, case={}): {}\nminimal counterexample: {:?}",
                seed, case, cur_msg, cur
            );
        }
    }
}

/// Assert helper for property bodies.
pub fn ensure(cond: bool, msg: &str) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        forall(1, 50, |r| r.below(100), |_| {
            count += 1;
            Ok(())
        });
        // 50 cases, no shrink calls.
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_panics_with_counterexample() {
        forall(2, 100, |r| r.below(1000), |&x| ensure(x < 500, "too big"));
    }

    #[test]
    fn shrinking_reaches_small_case() {
        let caught = std::panic::catch_unwind(|| {
            forall(3, 100, |r| r.below(10_000), |&x| ensure(x < 100, "big"));
        });
        let msg = format!("{:?}", caught.unwrap_err().downcast_ref::<String>().unwrap());
        // Greedy shrink should land near the boundary (definitely < 1000).
        let num: u64 = msg
            .split("counterexample: ")
            .nth(1)
            .unwrap()
            .trim_matches(|c: char| !c.is_ascii_digit())
            .parse()
            .unwrap();
        assert!(num >= 100 && num < 1000, "shrunk to {}", num);
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5u64, 6, 7, 8];
        let cands = v.shrink();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }
}
