//! Self-contained utility substrates.
//!
//! This image is offline and only ships the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde, clap, criterion,
//! proptest, rand, …) are unavailable. Everything the platform needs
//! beyond that closure is implemented here from scratch:
//!
//! * [`json`] — JSON parser/serializer (artifact manifests, API, persistence)
//! * [`rng`] — PCG64 PRNG with normal/choice/shuffle helpers
//! * [`argparse`] — declarative CLI argument parser
//! * [`table`] — plain-text table rendering
//! * [`plot`] — ASCII + SVG line charts (TensorBoard/Visdom stand-in)
//! * [`tomlcfg`] — TOML-lite config parser
//! * [`bench`] — criterion-like benchmark harness for `harness = false` benches
//! * [`clock`] — real + virtual clocks (virtual time drives the simulators)
//! * [`idgen`] — human-friendly unique ids (`nsml`-style session names)
//! * [`quickcheck`] — minimal property-testing harness

pub mod json;
pub mod rng;
pub mod argparse;
pub mod table;
pub mod plot;
pub mod tomlcfg;
pub mod bench;
pub mod clock;
pub mod idgen;
pub mod quickcheck;

/// Compute simple summary statistics over a slice.
pub fn stats(xs: &[f64]) -> Stats {
    if xs.is_empty() {
        return Stats {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            max: 0.0,
            p50: 0.0,
            p95: 0.0,
            p99: 0.0,
        };
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        let idx = ((n as f64 - 1.0) * p).round() as usize;
        sorted[idx.min(n - 1)]
    };
    Stats {
        n,
        mean,
        std: var.sqrt(),
        min: sorted[0],
        max: sorted[n - 1],
        p50: pct(0.5),
        p95: pct(0.95),
        p99: pct(0.99),
    }
}

/// Summary statistics produced by [`stats`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert_eq!(s.p99, 5.0);
    }

    #[test]
    fn stats_empty() {
        let s = stats(&[]);
        assert_eq!(s.n, 0);
    }
}
