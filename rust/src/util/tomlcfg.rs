//! TOML-lite config parser for platform configuration files.
//!
//! Supports `[section]` headers, `key = value` with strings, integers,
//! floats, booleans and flat arrays, plus `#` comments — the subset an
//! nsml.toml actually needs.

use std::collections::BTreeMap;

/// A parsed config: `section.key -> Value` (top-level keys use section "").
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<(String, String), Value>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

impl Config {
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let value = parse_value(v.trim()).map_err(|e| format!("line {}: {}", lineno + 1, e))?;
            cfg.entries.insert((section.clone(), k.trim().to_string()), value);
        }
        Ok(cfg)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_i64).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn sections(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().map(|(s, _)| s.clone()).collect();
        v.dedup();
        v
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let items: Result<Vec<Value>, String> =
            split_top_level(inner).iter().map(|p| parse_value(p.trim())).collect();
        return Ok(Value::Arr(items?));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value '{}'", s))
}

fn split_top_level(s: &str) -> Vec<String> {
    let mut parts = Vec::new();
    let mut cur = String::new();
    let mut in_str = false;
    for c in s.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                cur.push(c);
            }
            ',' if !in_str => {
                parts.push(cur.clone());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        parts.push(cur);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# nsml platform config
name = "alpha-cluster"   # inline comment
[cluster]
nodes = 10
gpus_per_node = 8
gpu_mem_gb = 24.0
[scheduler]
policy = "best_fit"
fast_path = true
priorities = [0, 1, 2]
tags = ["a", "b,c"]
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.str_or("", "name", "?"), "alpha-cluster");
        assert_eq!(c.int_or("cluster", "nodes", 0), 10);
        assert_eq!(c.float_or("cluster", "gpu_mem_gb", 0.0), 24.0);
        assert!(c.bool_or("scheduler", "fast_path", false));
        assert_eq!(c.str_or("scheduler", "policy", "?"), "best_fit");
    }

    #[test]
    fn arrays() {
        let c = Config::parse(SAMPLE).unwrap();
        match c.get("scheduler", "priorities").unwrap() {
            Value::Arr(v) => assert_eq!(v.len(), 3),
            _ => panic!(),
        }
        match c.get("scheduler", "tags").unwrap() {
            Value::Arr(v) => {
                assert_eq!(v[1].as_str(), Some("b,c")); // comma inside quotes survives
            }
            _ => panic!(),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int_or("x", "y", 7), 7);
        assert_eq!(c.str_or("x", "y", "dflt"), "dflt");
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = Config::parse("[s]\nbad line").unwrap_err();
        assert!(err.contains("line 2"), "{}", err);
    }

    #[test]
    fn int_vs_float_distinguished() {
        let c = Config::parse("a = 3\nb = 3.5").unwrap();
        assert_eq!(c.get("", "a"), Some(&Value::Int(3)));
        assert_eq!(c.get("", "b"), Some(&Value::Float(3.5)));
        assert_eq!(c.float_or("", "a", 0.0), 3.0); // int coerces to float
    }
}
