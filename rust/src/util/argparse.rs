//! Tiny declarative CLI argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, `-k value`, positional
//! arguments, subcommand dispatch, defaults, and auto-generated `--help`.

use std::collections::BTreeMap;

/// Specification for one option.
#[derive(Debug, Clone)]
struct OptSpec {
    long: String,
    short: Option<char>,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// A declarative argument parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String, bool)>, // (name, help, required)
    allow_trailing: bool,
}

/// Parse result: option values + positionals.
#[derive(Debug, Clone, Default)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
    trailing: Vec<String>,
}

impl ArgSpec {
    pub fn new(name: &str, about: &str) -> ArgSpec {
        ArgSpec {
            name: name.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
            allow_trailing: false,
        }
    }

    /// Add a boolean flag (`--verbose`).
    pub fn flag(mut self, long: &str, short: Option<char>, help: &str) -> Self {
        self.opts.push(OptSpec {
            long: long.to_string(),
            short,
            help: help.to_string(),
            takes_value: false,
            default: None,
        });
        self
    }

    /// Add a valued option (`--gpus 2`), optionally with a default.
    pub fn opt(mut self, long: &str, short: Option<char>, help: &str, default: Option<&str>) -> Self {
        self.opts.push(OptSpec {
            long: long.to_string(),
            short,
            help: help.to_string(),
            takes_value: true,
            default: default.map(str::to_string),
        });
        self
    }

    /// Add a positional argument.
    pub fn pos(mut self, name: &str, help: &str, required: bool) -> Self {
        self.positionals.push((name.to_string(), help.to_string(), required));
        self
    }

    /// Allow extra trailing positionals (collected into `Parsed::trailing`).
    pub fn trailing(mut self) -> Self {
        self.allow_trailing = true;
        self
    }

    /// Render a help string.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.opts.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        for (p, _, req) in &self.positionals {
            if *req {
                s.push_str(&format!(" <{}>", p));
            } else {
                s.push_str(&format!(" [{}]", p));
            }
        }
        if self.allow_trailing {
            s.push_str(" [...]");
        }
        s.push('\n');
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h, _) in &self.positionals {
                s.push_str(&format!("  {:<18} {}\n", p, h));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut left = String::new();
                if let Some(c) = o.short {
                    left.push_str(&format!("-{}, ", c));
                } else {
                    left.push_str("    ");
                }
                left.push_str(&format!("--{}", o.long));
                if o.takes_value {
                    left.push_str(" <v>");
                }
                let mut help = o.help.clone();
                if let Some(d) = &o.default {
                    help.push_str(&format!(" [default: {}]", d));
                }
                s.push_str(&format!("  {:<20} {}\n", left, help));
            }
        }
        s
    }

    /// Parse the given argument list.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut out = Parsed::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.long.clone(), d.clone());
            }
            if !o.takes_value {
                out.flags.insert(o.long.clone(), false);
            }
        }
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.help());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.long == key)
                    .ok_or_else(|| format!("unknown option --{} (try --help)", key))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i).cloned().ok_or_else(|| format!("--{} needs a value", key))?
                        }
                    };
                    out.values.insert(key, v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{} does not take a value", key));
                    }
                    out.flags.insert(key, true);
                }
            } else if let Some(short) = a.strip_prefix('-').filter(|s| s.len() == 1) {
                let c = short.chars().next().unwrap();
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.short == Some(c))
                    .ok_or_else(|| format!("unknown option -{} (try --help)", c))?;
                if spec.takes_value {
                    i += 1;
                    let v = args.get(i).cloned().ok_or_else(|| format!("-{} needs a value", c))?;
                    out.values.insert(spec.long.clone(), v);
                } else {
                    out.flags.insert(spec.long.clone(), true);
                }
            } else if out.positionals.len() < self.positionals.len() {
                out.positionals.push(a.clone());
            } else if self.allow_trailing {
                out.trailing.push(a.clone());
            } else {
                return Err(format!("unexpected argument '{}'", a));
            }
            i += 1;
        }
        for (idx, (name, _, required)) in self.positionals.iter().enumerate() {
            if *required && out.positionals.len() <= idx {
                return Err(format!("missing required argument <{}>\n\n{}", name, self.help()));
            }
        }
        Ok(out)
    }
}

impl Parsed {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }

    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(String::as_str)
    }

    pub fn trailing(&self) -> &[String] {
        &self.trailing
    }

    /// Typed getters with error messages.
    pub fn get_usize(&self, key: &str) -> Result<usize, String> {
        self.get(key)
            .ok_or_else(|| format!("missing --{}", key))?
            .parse()
            .map_err(|e| format!("--{}: {}", key, e))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, String> {
        self.get(key)
            .ok_or_else(|| format!("missing --{}", key))?
            .parse()
            .map_err(|e| format!("--{}: {}", key, e))
    }
}

#[cfg(test)]
fn svec(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|s| s.to_string()).collect()
}

/// Split `argv` into (subcommand, rest); empty subcommand if none given.
pub fn split_subcommand(args: &[String]) -> (String, Vec<String>) {
    match args.first() {
        Some(cmd) if !cmd.starts_with('-') => (cmd.clone(), args[1..].to_vec()),
        _ => (String::new(), args.to_vec()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("nsml run", "run a model")
            .opt("dataset", Some('d'), "dataset name", None)
            .opt("gpus", Some('g'), "gpu count", Some("1"))
            .flag("verbose", Some('v'), "chatty")
            .pos("entry", "entry file", true)
            .trailing()
    }

    #[test]
    fn parses_mixed() {
        let p = spec()
            .parse(&svec(&["main.py", "-d", "mnist", "--gpus=4", "--verbose", "x", "y"]))
            .unwrap();
        assert_eq!(p.pos(0), Some("main.py"));
        assert_eq!(p.get("dataset"), Some("mnist"));
        assert_eq!(p.get_usize("gpus").unwrap(), 4);
        assert!(p.flag("verbose"));
        assert_eq!(p.trailing(), &["x".to_string(), "y".to_string()]);
    }

    #[test]
    fn defaults_apply() {
        let p = spec().parse(&svec(&["main.py"])).unwrap();
        assert_eq!(p.get_usize("gpus").unwrap(), 1);
        assert!(!p.flag("verbose"));
        assert_eq!(p.get("dataset"), None);
    }

    #[test]
    fn missing_required_positional() {
        assert!(spec().parse(&[]).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&svec(&["main.py", "--wat"])).is_err());
    }

    #[test]
    fn value_missing_rejected() {
        assert!(spec().parse(&svec(&["main.py", "--dataset"])).is_err());
    }

    #[test]
    fn help_contains_options() {
        let h = spec().help();
        assert!(h.contains("--dataset"));
        assert!(h.contains("[default: 1]"));
        let err = spec().parse(&svec(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
    }

    #[test]
    fn subcommand_split() {
        let (cmd, rest) = split_subcommand(&svec(&["run", "main.py", "-d", "x"]));
        assert_eq!(cmd, "run");
        assert_eq!(rest.len(), 3);
        let (cmd, _) = split_subcommand(&svec(&["--help"]));
        assert_eq!(cmd, "");
    }
}
