//! Clock abstraction: real wall-clock and virtual (simulated) time.
//!
//! The cluster/scheduler/container simulators are written against
//! [`Clock`] so tests and benches run in virtual time (deterministic,
//! instant) while live platform runs use wall-clock time.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

/// Milliseconds since an arbitrary epoch.
pub type Millis = u64;

/// A source of monotonically nondecreasing milliseconds.
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> Millis;
    /// Advance time by `ms`. Real clocks sleep; virtual clocks jump.
    fn sleep_ms(&self, ms: Millis);
}

/// Wall-clock time (epoch = UNIX epoch).
#[derive(Debug, Default, Clone)]
pub struct RealClock;

impl Clock for RealClock {
    fn now_ms(&self) -> Millis {
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_millis() as u64
    }

    fn sleep_ms(&self, ms: Millis) {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

/// Virtual time: starts at 0, advances only via [`Clock::sleep_ms`] /
/// [`SimClock::advance`]. Shareable across threads.
#[derive(Debug, Default, Clone)]
pub struct SimClock {
    now: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { now: Arc::new(AtomicU64::new(0)) }
    }

    pub fn advance(&self, ms: Millis) {
        self.now.fetch_add(ms, Ordering::SeqCst);
    }

    pub fn set(&self, ms: Millis) {
        self.now.store(ms, Ordering::SeqCst);
    }
}

impl Clock for SimClock {
    fn now_ms(&self) -> Millis {
        self.now.load(Ordering::SeqCst)
    }

    fn sleep_ms(&self, ms: Millis) {
        self.advance(ms);
    }
}

/// A shared trait object clock handle.
pub type SharedClock = Arc<dyn Clock>;

/// Convenience constructors.
pub fn real_clock() -> SharedClock {
    Arc::new(RealClock)
}

pub fn sim_clock() -> (SharedClock, SimClock) {
    let sim = SimClock::new();
    (Arc::new(sim.clone()), sim)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_clock_advances() {
        let (clock, handle) = sim_clock();
        assert_eq!(clock.now_ms(), 0);
        handle.advance(100);
        assert_eq!(clock.now_ms(), 100);
        clock.sleep_ms(50);
        assert_eq!(clock.now_ms(), 150);
        handle.set(10);
        assert_eq!(clock.now_ms(), 10);
    }

    #[test]
    fn real_clock_monotone_enough() {
        let c = RealClock;
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
        assert!(a > 1_600_000_000_000); // after 2020
    }

    #[test]
    fn sim_clock_shared_across_clones() {
        let (clock, handle) = sim_clock();
        let c2 = clock.clone();
        handle.advance(42);
        assert_eq!(c2.now_ms(), 42);
    }
}
