//! The active session runner: drives a [`TrainableModel`] over a
//! [`DataGen`] stream with metric logging, periodic checkpoints,
//! pause/resume and in-training hyperparameter edits (§3.3).

use super::{SessionSpec, SessionState, SessionStore};
use crate::data::DataGen;
use crate::events::{EventKind, EventLog, Level};
use crate::runtime::{Batch, Engine, TrainableModel};
use crate::storage::{Checkpoint, CheckpointStore};
use crate::util::clock::SharedClock;
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;
use std::sync::Arc;

/// Result of driving a session chunk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunStatus {
    /// More steps remain.
    InProgress,
    /// Reached `total_steps`.
    Completed,
}

/// A live training execution (the code running "inside the container").
pub struct SessionRun {
    pub spec: SessionSpec,
    model: TrainableModel,
    gen: Box<dyn DataGen>,
    ckpts: CheckpointStore,
    store: SessionStore,
    events: EventLog,
    clock: SharedClock,
    lr: f32,
    steps_done: u64,
    last_eval: (f32, f32),
    last_eval_at: u64,
    last_ckpt_at: u64,
}

impl SessionRun {
    /// Start fresh: init params from the session seed.
    pub fn start(
        engine: Arc<Engine>,
        spec: SessionSpec,
        gen: Box<dyn DataGen>,
        ckpts: CheckpointStore,
        store: SessionStore,
        events: EventLog,
        clock: SharedClock,
    ) -> Result<SessionRun> {
        let model = TrainableModel::init(engine, &spec.model, spec.seed as i32)?;
        events.info("session", &spec.id, format!("training {} on {} started", spec.model, spec.dataset));
        publish_state(&events, &store, &spec.id, "running", 0);
        store.update(&spec.id, |r| r.state = SessionState::Running);
        let lr = spec.lr as f32;
        Ok(SessionRun {
            spec,
            model,
            gen,
            ckpts,
            store,
            events,
            clock,
            lr,
            steps_done: 0,
            last_eval: (f32::NAN, f32::NAN),
            last_eval_at: 0,
            last_ckpt_at: 0,
        })
    }

    /// Resume a paused/killed session from its latest checkpoint
    /// (the §3.3 "download a model from storage container and resume").
    pub fn resume(
        engine: Arc<Engine>,
        spec: SessionSpec,
        gen: Box<dyn DataGen>,
        ckpts: CheckpointStore,
        store: SessionStore,
        events: EventLog,
        clock: SharedClock,
    ) -> Result<SessionRun> {
        let ckpt = ckpts
            .latest(&spec.id)
            .ok_or_else(|| anyhow!("session {} has no checkpoint to resume from", spec.id))?;
        let bytes = ckpts.load_params(&ckpt)?;
        let model = TrainableModel::from_checkpoint(engine, &spec.model, &bytes)?;
        let lr = ckpt.hparams.get("lr").copied().unwrap_or(spec.lr) as f32;
        events.info(
            "session",
            &spec.id,
            format!("resumed from checkpoint at step {} (lr={})", ckpt.step, lr),
        );
        publish_state(&events, &store, &spec.id, "running", ckpt.step);
        store.update(&spec.id, |r| r.state = SessionState::Running);
        Ok(SessionRun {
            steps_done: ckpt.step,
            last_eval_at: ckpt.step,
            last_ckpt_at: ckpt.step,
            spec,
            model,
            gen,
            ckpts,
            store,
            events,
            clock,
            lr,
            last_eval: (f32::NAN, f32::NAN),
        })
    }

    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    pub fn lr(&self) -> f32 {
        self.lr
    }

    /// Edit the learning rate mid-training (hyperparameter tuning in
    /// training time). Takes effect on the next step.
    pub fn set_lr(&mut self, lr: f64) {
        self.events.info("session", &self.spec.id, format!("lr changed {} -> {}", self.lr, lr));
        self.lr = lr as f32;
    }

    fn hparams(&self) -> BTreeMap<String, f64> {
        let mut m = BTreeMap::new();
        m.insert("lr".to_string(), self.lr as f64);
        m.insert("seed".to_string(), self.spec.seed as f64);
        m
    }

    /// Drive up to `max_steps` further steps (bounded by `total_steps`).
    pub fn step_chunk(&mut self, max_steps: u64) -> Result<RunStatus> {
        let batch_n = self.model.manifest().batch;
        let scan_k = self.model.manifest().scan_k as u64;
        let target = self.spec.total_steps.min(self.steps_done + max_steps);
        while self.steps_done < target {
            let loss = if self.spec.use_scan && target - self.steps_done >= scan_k {
                let batches: Vec<Batch> = (0..scan_k).map(|_| self.gen.batch(batch_n)).collect();
                let l = self.model.train_scan(&batches, self.lr)?;
                self.steps_done += scan_k;
                l
            } else {
                let batch = self.gen.batch(batch_n);
                let l = self.model.train_step(&batch, self.lr)?;
                self.steps_done += 1;
                l
            };
            if !loss.is_finite() {
                publish_state(&self.events, &self.store, &self.spec.id, "failed", self.steps_done);
                self.store.update(&self.spec.id, |r| {
                    r.state = SessionState::Failed;
                    r.failure = Some(format!("non-finite loss at step {}", self.steps_done));
                });
                return Err(anyhow!("session {}: non-finite loss", self.spec.id));
            }
            let step = self.steps_done;
            self.store.update(&self.spec.id, |r| {
                r.steps_done = step;
                r.metrics.log(step, "train_loss", loss as f64);
            });
            // Periodic hooks fire on boundary crossings (steps may advance
            // by scan_k at a time, so exact-multiple checks would skip).
            if self.spec.eval_every > 0 && step / self.spec.eval_every > self.last_eval_at / self.spec.eval_every {
                self.last_eval_at = step;
                self.run_eval()?;
            }
            if self.spec.checkpoint_every > 0
                && step / self.spec.checkpoint_every > self.last_ckpt_at / self.spec.checkpoint_every
            {
                self.last_ckpt_at = step;
                self.checkpoint()?;
            }
        }
        if self.steps_done >= self.spec.total_steps {
            self.finish()?;
            Ok(RunStatus::Completed)
        } else {
            Ok(RunStatus::InProgress)
        }
    }

    fn run_eval(&mut self) -> Result<()> {
        let batch = self.gen.eval_batch(self.model.manifest().batch);
        let (loss, metric) = self.model.evaluate(&batch)?;
        self.last_eval = (loss, metric);
        let step = self.steps_done;
        let metric_name = self.model.manifest().metric_name.clone();
        let lower = self.model.manifest().lower_is_better;
        // Typed metric emission: bus consumers (web dashboards, logs
        // followers) see evals without reading the record store.
        self.events.bus().publish(
            Level::Debug,
            "session",
            &self.spec.id,
            EventKind::MetricReported { name: "eval_loss".into(), step, value: loss as f64 },
        );
        self.events.bus().publish(
            Level::Info,
            "session",
            &self.spec.id,
            EventKind::MetricReported { name: metric_name.clone(), step, value: metric as f64 },
        );
        self.store.update(&self.spec.id, |r| {
            r.metrics.log(step, "eval_loss", loss as f64);
            r.metrics.log(step, &metric_name, metric as f64);
            let better = match r.best_metric {
                None => true,
                Some(b) => {
                    if lower {
                        (metric as f64) < b
                    } else {
                        (metric as f64) > b
                    }
                }
            };
            if better {
                r.best_metric = Some(metric as f64);
            }
        });
        Ok(())
    }

    /// Persist a checkpoint now.
    pub fn checkpoint(&mut self) -> Result<Checkpoint> {
        let bytes = self.model.params_bytes()?;
        let ck = self.ckpts.save(
            &self.spec.id,
            self.steps_done,
            self.last_eval.0 as f64,
            &self.hparams(),
            &bytes,
            self.clock.now_ms(),
        )?;
        self.events.bus().publish(
            Level::Debug,
            "session",
            &self.spec.id,
            EventKind::CheckpointSaved { step: self.steps_done, object: ck.params.0.clone() },
        );
        Ok(ck)
    }

    /// Pause: checkpoint + mark paused (user can now edit hparams).
    pub fn pause(&mut self) -> Result<Checkpoint> {
        let ck = self.checkpoint()?;
        publish_state(&self.events, &self.store, &self.spec.id, "paused", self.steps_done);
        self.store.update(&self.spec.id, |r| r.state = SessionState::Paused);
        self.events.info("session", &self.spec.id, format!("paused at step {}", self.steps_done));
        Ok(ck)
    }

    /// Rewind to an earlier checkpointed step (reproduce past state).
    pub fn rewind_to(&mut self, step: u64) -> Result<()> {
        let ck = self
            .ckpts
            .at_step(&self.spec.id, step)
            .ok_or_else(|| anyhow!("no checkpoint at step {}", step))?;
        let bytes = self.ckpts.load_params(&ck)?;
        self.model.load_params(&bytes)?;
        self.steps_done = step;
        self.events.info("session", &self.spec.id, format!("rewound to step {}", step));
        Ok(())
    }

    /// Final eval + checkpoint + mark done; returns (loss, metric).
    pub fn finish(&mut self) -> Result<(f32, f32)> {
        self.run_eval()?;
        self.checkpoint()?;
        let (loss, metric) = self.last_eval;
        let now = self.clock.now_ms();
        publish_state(&self.events, &self.store, &self.spec.id, "done", self.steps_done);
        self.store.update(&self.spec.id, |r| {
            r.state = SessionState::Done;
            r.finished_at_ms = Some(now);
        });
        self.events.info(
            "session",
            &self.spec.id,
            format!("done at step {}: loss={:.4} metric={:.4}", self.steps_done, loss, metric),
        );
        Ok((loss, metric))
    }

    /// Run one inference through the trained model (the `nsml infer` demo).
    pub fn infer(&self, x: &crate::runtime::TensorData) -> Result<Vec<f32>> {
        self.model.infer(x)
    }

    pub fn model(&self) -> &TrainableModel {
        &self.model
    }
}

/// Publish a typed `StateChanged` event. `from` is read from the store
/// because the caller has not applied the transition yet (`"new"` when
/// no record exists, matching the submission transition); `failed`
/// transitions surface at error level so log followers see them.
fn publish_state(events: &EventLog, store: &SessionStore, id: &str, to: &str, step: u64) {
    let from =
        store.get(id).map(|r| r.state.as_str().to_string()).unwrap_or_else(|| "new".into());
    let level = if to == "failed" { Level::Error } else { Level::Info };
    events.bus().publish(
        level,
        "session",
        id,
        EventKind::StateChanged { from, to: to.to_string(), step },
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generator_for;
    use crate::session::SessionRecord;
    use crate::storage::ObjectStore;
    use crate::util::clock::sim_clock;
    use std::path::PathBuf;

    fn engine() -> Option<Arc<Engine>> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then(|| Arc::new(Engine::new(&dir).unwrap()))
    }

    fn setup(spec: &SessionSpec) -> (CheckpointStore, SessionStore, EventLog, SharedClock) {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        let ckpts = CheckpointStore::new(ObjectStore::memory());
        let store = SessionStore::new();
        store.insert(SessionRecord::new(spec.clone(), 0));
        (ckpts, store, events, clock)
    }

    #[test]
    fn session_trains_to_completion_and_improves() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut spec = SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp");
        spec.total_steps = 60;
        spec.eval_every = 20;
        spec.checkpoint_every = 30;
        let (ckpts, store, events, clock) = setup(&spec);
        let gen = generator_for("mnist_mlp", 1).unwrap();
        let mut run =
            SessionRun::start(engine, spec, gen, ckpts.clone(), store.clone(), events, clock).unwrap();
        let status = run.step_chunk(1000).unwrap();
        assert_eq!(status, RunStatus::Completed);

        let rec = store.get("kim/mnist/1").unwrap();
        assert_eq!(rec.state, SessionState::Done);
        assert_eq!(rec.steps_done, 60);
        let losses = rec.metrics.series("train_loss");
        assert_eq!(losses.len(), 60);
        // Loss at the end far below the start (procedural digits are easy).
        assert!(losses.last().unwrap().1 < losses[0].1 * 0.7, "{:?}", (losses[0], losses[losses.len()-1]));
        assert!(rec.best_metric.unwrap() > 0.3, "accuracy {:?}", rec.best_metric);
        // Checkpoints at 30, 60 and the final one.
        assert!(ckpts.list("kim/mnist/1").len() >= 2);
    }

    #[test]
    fn pause_edit_lr_resume() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut spec = SessionSpec::new("kim/mnist/2", "kim", "mnist", "mnist_mlp");
        spec.total_steps = 40;
        spec.lr = 0.2;
        let (ckpts, store, events, clock) = setup(&spec);
        let gen = generator_for("mnist_mlp", 2).unwrap();
        let mut run = SessionRun::start(
            engine.clone(),
            spec.clone(),
            gen,
            ckpts.clone(),
            store.clone(),
            events.clone(),
            clock.clone(),
        )
        .unwrap();
        assert_eq!(run.step_chunk(20).unwrap(), RunStatus::InProgress);
        run.pause().unwrap();
        assert_eq!(store.get("kim/mnist/2").unwrap().state, SessionState::Paused);
        drop(run);

        // Resume with an edited lr: the §3.3 REPL tuning flow.
        let gen2 = generator_for("mnist_mlp", 2).unwrap();
        let mut resumed =
            SessionRun::resume(engine, spec, gen2, ckpts, store.clone(), events, clock).unwrap();
        assert_eq!(resumed.steps_done(), 20);
        resumed.set_lr(0.01);
        assert!((resumed.lr() - 0.01).abs() < 1e-6);
        assert_eq!(resumed.step_chunk(1000).unwrap(), RunStatus::Completed);
        assert_eq!(store.get("kim/mnist/2").unwrap().state, SessionState::Done);
    }

    #[test]
    fn rewind_to_checkpoint() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut spec = SessionSpec::new("kim/mnist/3", "kim", "mnist", "mnist_mlp");
        spec.total_steps = 30;
        spec.checkpoint_every = 10;
        let (ckpts, store, events, clock) = setup(&spec);
        let gen = generator_for("mnist_mlp", 3).unwrap();
        let mut run =
            SessionRun::start(engine, spec, gen, ckpts, store.clone(), events, clock).unwrap();
        run.step_chunk(25).unwrap();
        assert_eq!(run.steps_done(), 25);
        run.rewind_to(10).unwrap();
        assert_eq!(run.steps_done(), 10);
        assert!(run.rewind_to(7).is_err()); // no checkpoint there
    }

    #[test]
    fn scan_mode_counts_steps_correctly() {
        let Some(engine) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut spec = SessionSpec::new("kim/mnist/4", "kim", "mnist", "mnist_mlp");
        spec.total_steps = 32;
        spec.use_scan = true;
        spec.eval_every = 0;
        spec.checkpoint_every = 0;
        let (ckpts, store, events, clock) = setup(&spec);
        let gen = generator_for("mnist_mlp", 4).unwrap();
        let mut run =
            SessionRun::start(engine, spec, gen, ckpts, store.clone(), events, clock).unwrap();
        assert_eq!(run.step_chunk(1000).unwrap(), RunStatus::Completed);
        assert_eq!(run.steps_done(), 32); // 4 scan calls × k=8
        let rec = store.get("kim/mnist/4").unwrap();
        assert_eq!(rec.metrics.series("train_loss").len(), 4); // one log per scan
    }
}
