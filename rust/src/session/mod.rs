//! Training sessions: NSML's unit of experiment (§3.3, §3.4).
//!
//! A session is one `nsml run`: code + dataset + hyperparameters placed
//! on a node, training inside an ML container, streaming metrics, saving
//! checkpoints, and supporting the paper's signature feature —
//! **hyperparameter tuning in training time** by pausing user code,
//! loading a model from the storage container, editing hyperparameters
//! and resuming (§3.3).

mod metrics;
mod run;

pub use metrics::{MetricLog, MetricPoint};
pub use run::{RunStatus, SessionRun};

use crate::scheduler::Priority;
use crate::util::clock::Millis;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Session lifecycle (superset of the scheduler job lifecycle).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionState {
    Queued,
    Preparing,
    Running,
    Paused,
    Done,
    Failed,
    Stopped,
}

impl SessionState {
    pub fn as_str(&self) -> &'static str {
        match self {
            SessionState::Queued => "queued",
            SessionState::Preparing => "preparing",
            SessionState::Running => "running",
            SessionState::Paused => "paused",
            SessionState::Done => "done",
            SessionState::Failed => "failed",
            SessionState::Stopped => "stopped",
        }
    }

    /// Inverse of [`SessionState::as_str`] (wire-format deserialization).
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(s: &str) -> Option<SessionState> {
        match s {
            "queued" => Some(SessionState::Queued),
            "preparing" => Some(SessionState::Preparing),
            "running" => Some(SessionState::Running),
            "paused" => Some(SessionState::Paused),
            "done" => Some(SessionState::Done),
            "failed" => Some(SessionState::Failed),
            "stopped" => Some(SessionState::Stopped),
            _ => None,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(self, SessionState::Done | SessionState::Failed | SessionState::Stopped)
    }
}

/// What the user asked for (the `nsml run` arguments).
#[derive(Debug, Clone)]
pub struct SessionSpec {
    pub id: String,
    pub user: String,
    pub dataset: String,
    pub model: String,
    pub gpus: usize,
    pub priority: Priority,
    pub total_steps: u64,
    pub lr: f64,
    pub seed: u64,
    pub checkpoint_every: u64,
    pub eval_every: u64,
    /// Use the scan-fused train path (L2 perf variant).
    pub use_scan: bool,
}

impl SessionSpec {
    pub fn new(id: &str, user: &str, dataset: &str, model: &str) -> SessionSpec {
        SessionSpec {
            id: id.to_string(),
            user: user.to_string(),
            dataset: dataset.to_string(),
            model: model.to_string(),
            gpus: 1,
            priority: Priority::Normal,
            total_steps: 200,
            lr: 0.1,
            seed: 0,
            checkpoint_every: 50,
            eval_every: 25,
            use_scan: false,
        }
    }
}

/// Mutable session record tracked by the platform.
#[derive(Debug, Clone)]
pub struct SessionRecord {
    pub spec: SessionSpec,
    pub state: SessionState,
    pub node: Option<crate::cluster::NodeId>,
    pub container: Option<String>,
    pub steps_done: u64,
    pub metrics: MetricLog,
    pub best_metric: Option<f64>,
    pub submitted_at_ms: Millis,
    pub finished_at_ms: Option<Millis>,
    pub failure: Option<String>,
    /// Times this session was auto-recovered after a node loss (§4.2).
    pub recoveries: u32,
    /// Times this session was preempted by fair-share quota
    /// enforcement (checkpointed, paused and re-queued for a waiting
    /// user).
    pub preemptions: u32,
    /// Currently evicted and waiting for re-admission: distinguishes
    /// a preemption resume (quota enforcement) from a failure
    /// recovery, so `recoveries` stays honest.
    pub preempted: bool,
}

impl SessionRecord {
    pub fn new(spec: SessionSpec, now_ms: Millis) -> SessionRecord {
        SessionRecord {
            spec,
            state: SessionState::Queued,
            node: None,
            container: None,
            steps_done: 0,
            metrics: MetricLog::new(),
            best_metric: None,
            submitted_at_ms: now_ms,
            finished_at_ms: None,
            failure: None,
            recoveries: 0,
            preemptions: 0,
            preempted: false,
        }
    }
}

/// Thread-safe store of all sessions (the master's bookkeeping).
#[derive(Clone, Default)]
pub struct SessionStore {
    inner: Arc<Mutex<BTreeMap<String, SessionRecord>>>,
}

impl SessionStore {
    pub fn new() -> SessionStore {
        SessionStore::default()
    }

    pub fn insert(&self, rec: SessionRecord) {
        self.inner.lock().unwrap().insert(rec.spec.id.clone(), rec);
    }

    pub fn get(&self, id: &str) -> Option<SessionRecord> {
        self.inner.lock().unwrap().get(id).cloned()
    }

    /// Apply a mutation to one session record.
    pub fn update<F: FnOnce(&mut SessionRecord)>(&self, id: &str, f: F) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if let Some(rec) = inner.get_mut(id) {
            f(rec);
            true
        } else {
            false
        }
    }

    /// Flip a non-terminal record to `Failed` with a reason (no-op on
    /// terminal records; keeps the first recorded failure message).
    pub fn mark_failed(&self, id: &str, err: &str) -> bool {
        self.update(id, |r| {
            if !r.state.is_terminal() {
                r.state = SessionState::Failed;
                if r.failure.is_none() {
                    r.failure = Some(err.to_string());
                }
            }
        })
    }

    pub fn list(&self) -> Vec<SessionRecord> {
        self.inner.lock().unwrap().values().cloned().collect()
    }

    pub fn by_state(&self, state: SessionState) -> Vec<SessionRecord> {
        self.inner.lock().unwrap().values().filter(|r| r.state == state).cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_crud() {
        let store = SessionStore::new();
        let spec = SessionSpec::new("kim/mnist/1", "kim", "mnist", "mnist_mlp");
        store.insert(SessionRecord::new(spec, 100));
        assert_eq!(store.len(), 1);
        assert!(store.update("kim/mnist/1", |r| {
            r.state = SessionState::Running;
            r.steps_done = 10;
        }));
        let rec = store.get("kim/mnist/1").unwrap();
        assert_eq!(rec.state, SessionState::Running);
        assert_eq!(rec.steps_done, 10);
        assert!(!store.update("missing", |_| {}));
        assert_eq!(store.by_state(SessionState::Running).len(), 1);
        assert_eq!(store.by_state(SessionState::Done).len(), 0);
    }

    #[test]
    fn terminal_states() {
        assert!(SessionState::Done.is_terminal());
        assert!(SessionState::Failed.is_terminal());
        assert!(SessionState::Stopped.is_terminal());
        assert!(!SessionState::Running.is_terminal());
        assert!(!SessionState::Paused.is_terminal());
        assert_eq!(SessionState::Paused.as_str(), "paused");
    }

    #[test]
    fn state_strings_round_trip() {
        for s in [
            SessionState::Queued,
            SessionState::Preparing,
            SessionState::Running,
            SessionState::Paused,
            SessionState::Done,
            SessionState::Failed,
            SessionState::Stopped,
        ] {
            assert_eq!(SessionState::from_str(s.as_str()), Some(s));
        }
        assert_eq!(SessionState::from_str("nope"), None);
    }
}
