//! Per-session metric streams (the TensorBoard/Visdom scalar log).

use crate::util::plot::Series;

/// One logged scalar.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricPoint {
    pub step: u64,
    pub name: String,
    pub value: f64,
}

/// Append-only metric log with per-name series extraction.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricLog {
    points: Vec<MetricPoint>,
}

impl MetricLog {
    pub fn new() -> MetricLog {
        MetricLog::default()
    }

    pub fn log(&mut self, step: u64, name: &str, value: f64) {
        self.points.push(MetricPoint { step, name: name.to_string(), value });
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// All points of one metric as (step, value).
    pub fn series(&self, name: &str) -> Vec<(f64, f64)> {
        self.points
            .iter()
            .filter(|p| p.name == name)
            .map(|p| (p.step as f64, p.value))
            .collect()
    }

    /// Series object for the plot renderers.
    pub fn plot_series(&self, name: &str) -> Series {
        Series::new(name, self.series(name))
    }

    pub fn latest(&self, name: &str) -> Option<f64> {
        self.points.iter().rev().find(|p| p.name == name).map(|p| p.value)
    }

    pub fn best(&self, name: &str, lower_is_better: bool) -> Option<f64> {
        let vals = self.series(name);
        if vals.is_empty() {
            return None;
        }
        let iter = vals.into_iter().map(|(_, v)| v);
        Some(if lower_is_better {
            iter.fold(f64::INFINITY, f64::min)
        } else {
            iter.fold(f64::NEG_INFINITY, f64::max)
        })
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.points.iter().map(|p| p.name.clone()).collect();
        names.sort();
        names.dedup();
        names
    }

    pub fn points(&self) -> &[MetricPoint] {
        &self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_and_extract() {
        let mut m = MetricLog::new();
        m.log(0, "loss", 2.0);
        m.log(10, "loss", 1.5);
        m.log(10, "acc", 0.4);
        m.log(20, "loss", 1.2);
        assert_eq!(m.series("loss"), vec![(0.0, 2.0), (10.0, 1.5), (20.0, 1.2)]);
        assert_eq!(m.latest("loss"), Some(1.2));
        assert_eq!(m.latest("acc"), Some(0.4));
        assert_eq!(m.latest("nope"), None);
        assert_eq!(m.names(), vec!["acc", "loss"]);
        assert_eq!(m.len(), 4);
    }

    #[test]
    fn best_respects_direction() {
        let mut m = MetricLog::new();
        m.log(0, "loss", 2.0);
        m.log(1, "loss", 0.5);
        m.log(2, "loss", 1.0);
        assert_eq!(m.best("loss", true), Some(0.5));
        assert_eq!(m.best("loss", false), Some(2.0));
        assert_eq!(m.best("x", true), None);
    }

    #[test]
    fn plot_series_named() {
        let mut m = MetricLog::new();
        m.log(0, "loss", 1.0);
        let s = m.plot_series("loss");
        assert_eq!(s.name, "loss");
        assert_eq!(s.points.len(), 1);
    }
}
