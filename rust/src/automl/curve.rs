//! Learning-curve model: fit `loss(t) = a * (t + 1)^(-b) + c` to observed
//! (step, loss) points and extrapolate.
//!
//! Fitting: grid over the decay exponent `b`; for each `b` the model is
//! linear in `(a, c)` and solved by least squares. This tiny model is
//! remarkably effective at ranking runs early — which is all the AutoML
//! early-stopper needs.

/// A fitted power-law learning curve.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CurveFit {
    pub a: f64,
    pub b: f64,
    pub c: f64,
    /// Mean squared residual of the fit.
    pub mse: f64,
}

impl CurveFit {
    /// Fit to (step, loss) points. Needs >= 3 points.
    pub fn fit(points: &[(f64, f64)]) -> Option<CurveFit> {
        if points.len() < 3 {
            return None;
        }
        let mut best: Option<CurveFit> = None;
        let consider = |b: f64, best: &mut Option<CurveFit>| {
            if let Some((a, c, mse)) = Self::solve_linear(points, b) {
                if best.map_or(true, |f| mse < f.mse) {
                    *best = Some(CurveFit { a, b, c, mse });
                }
            }
        };
        // Coarse pass over decay exponents, then a fine pass around the
        // best coarse value.
        for i in 0..=40 {
            consider(0.05 + i as f64 * 0.1, &mut best);
        }
        if let Some(coarse) = best {
            for i in 0..=40 {
                let b = (coarse.b - 0.1 + i as f64 * 0.005).max(0.01);
                consider(b, &mut best);
            }
        }
        best
    }

    /// Least squares for a, c given fixed b: loss ~ a*phi(t) + c.
    fn solve_linear(points: &[(f64, f64)], b: f64) -> Option<(f64, f64, f64)> {
        let n = points.len() as f64;
        let mut s_p = 0.0;
        let mut s_y = 0.0;
        let mut s_pp = 0.0;
        let mut s_py = 0.0;
        for &(t, y) in points {
            let p = (t + 1.0).powf(-b);
            s_p += p;
            s_y += y;
            s_pp += p * p;
            s_py += p * y;
        }
        let det = n * s_pp - s_p * s_p;
        if det.abs() < 1e-12 {
            return None;
        }
        let a = (n * s_py - s_p * s_y) / det;
        let c = (s_y - a * s_p) / n;
        let mut mse = 0.0;
        for &(t, y) in points {
            let pred = a * (t + 1.0).powf(-b) + c;
            mse += (y - pred) * (y - pred);
        }
        Some((a, c, mse / n))
    }

    /// Predicted loss at a step.
    pub fn predict(&self, step: f64) -> f64 {
        self.a * (step + 1.0).powf(-self.b) + self.c
    }

    /// Predicted asymptotic loss.
    pub fn asymptote(&self) -> f64 {
        self.c
    }
}

/// Convenience: predict a run's final loss from its partial curve.
/// Returns `None` when fewer than 3 points are available.
pub fn predict_final(points: &[(f64, f64)], final_step: f64) -> Option<f64> {
    CurveFit::fit(points).map(|f| f.predict(final_step))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn synth_curve(a: f64, b: f64, c: f64, n: usize, noise: f64, seed: u64) -> Vec<(f64, f64)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|i| {
                let t = (i * 10) as f64;
                (t, a * (t + 1.0).powf(-b) + c + rng.gauss(0.0, noise))
            })
            .collect()
    }

    #[test]
    fn recovers_clean_power_law() {
        let pts = synth_curve(2.0, 0.5, 0.3, 20, 0.0, 1);
        let fit = CurveFit::fit(&pts).unwrap();
        assert!((fit.c - 0.3).abs() < 0.05, "{:?}", fit);
        assert!((fit.predict(1000.0) - (2.0 * 1001.0f64.powf(-0.5) + 0.3)).abs() < 0.05);
        assert!(fit.mse < 1e-4);
    }

    #[test]
    fn extrapolates_under_noise() {
        let pts = synth_curve(3.0, 0.7, 0.5, 15, 0.02, 2);
        let pred = predict_final(&pts, 2000.0).unwrap();
        assert!((pred - 0.5).abs() < 0.15, "pred {}", pred);
    }

    #[test]
    fn ranks_two_runs_early() {
        // Run A converges to 0.2, run B to 0.8; at 1/10 of the budget the
        // fits must already order them correctly.
        let a = synth_curve(2.0, 0.6, 0.2, 10, 0.01, 3);
        let b = synth_curve(2.0, 0.6, 0.8, 10, 0.01, 4);
        let pa = predict_final(&a, 1000.0).unwrap();
        let pb = predict_final(&b, 1000.0).unwrap();
        assert!(pa < pb, "{} vs {}", pa, pb);
    }

    #[test]
    fn too_few_points_is_none() {
        assert!(CurveFit::fit(&[(0.0, 1.0), (1.0, 0.9)]).is_none());
        assert!(predict_final(&[], 100.0).is_none());
    }

    #[test]
    fn flat_curve_predicts_flat() {
        let pts: Vec<(f64, f64)> = (0..10).map(|i| (i as f64, 1.0)).collect();
        let fit = CurveFit::fit(&pts).unwrap();
        assert!((fit.predict(1e6) - 1.0).abs() < 0.05);
    }
}
