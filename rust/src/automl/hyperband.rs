//! Hyperband (the "More AutoML features will be added in future" line in
//! §5, implemented): multiple successive-halving brackets trading off
//! "many configs, short budgets" vs "few configs, long budgets", so no
//! single aggressiveness setting has to be guessed.

use super::search::{SearchOutcome, SuccessiveHalving, TrialRunner};
use crate::util::rng::Rng;

/// Hyperband over a log-uniform lr range.
pub struct Hyperband {
    pub lr_log10_range: (f64, f64),
    /// Maximum budget (steps) any single trial may receive.
    pub max_steps_per_trial: u64,
    pub eta: usize,
    pub seed: u64,
}

/// A bracket's shape: how many configs enter, with how many rungs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bracket {
    pub configs: usize,
    pub rungs: usize,
}

impl Hyperband {
    /// The bracket schedule: s_max+1 brackets, from aggressive (many
    /// configs, heavy early stopping) to conservative (few configs, full
    /// budget each).
    pub fn brackets(&self) -> Vec<Bracket> {
        let eta = self.eta as f64;
        // s_max = floor(log_eta(max_budget)) capped so configs stay sane.
        let s_max = ((self.max_steps_per_trial as f64).log(eta).floor() as usize).min(3);
        (0..=s_max)
            .rev()
            .map(|s| Bracket { configs: (self.eta.pow(s as u32)).max(1), rungs: s + 1 })
            .collect()
    }

    /// Run all brackets against runner-building closure `make_runner`
    /// (each bracket gets a fresh set of trials). Returns the best
    /// outcome across brackets plus the per-bracket results.
    pub fn run<F>(&self, mut make_runner: F) -> (SearchOutcome, Vec<SearchOutcome>)
    where
        F: FnMut(usize) -> Box<dyn TrialRunner>,
    {
        let mut rng = Rng::new(self.seed);
        let mut outcomes = Vec::new();
        for bracket in self.brackets() {
            let lrs: Vec<f64> = (0..bracket.configs)
                .map(|_| 10f64.powf(rng.uniform(self.lr_log10_range.0, self.lr_log10_range.1)))
                .collect();
            let mut runner = make_runner(bracket.configs);
            let outcome = SuccessiveHalving {
                lrs,
                total_steps_per_trial: self.max_steps_per_trial,
                eta: self.eta,
                rungs: bracket.rungs,
            }
            .run(runner.as_mut());
            outcomes.push(outcome);
        }
        let best = outcomes
            .iter()
            .min_by(|a, b| a.best_loss.partial_cmp(&b.best_loss).unwrap())
            .expect("at least one bracket")
            .clone();
        (best, outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Same synthetic landscape as search.rs: optimum at lr = 0.1.
    struct SynthRunner {
        steps: Vec<u64>,
        lrs: Vec<f64>,
    }

    impl SynthRunner {
        fn new(n: usize) -> SynthRunner {
            SynthRunner { steps: vec![0; n], lrs: vec![f64::NAN; n] }
        }

        fn loss_at(lr: f64, t: f64) -> f64 {
            let opt = (lr.log10() + 1.0).abs();
            0.2 + opt * opt + 2.0 * (t + 1.0).powf(-0.6)
        }
    }

    impl TrialRunner for SynthRunner {
        fn extend(&mut self, trial: usize, lr: f64, steps: u64) -> Vec<(f64, f64)> {
            self.lrs[trial] = lr;
            self.steps[trial] += steps;
            (1..=self.steps[trial]).map(|t| (t as f64, Self::loss_at(lr, t as f64))).collect()
        }

        fn current_loss(&mut self, trial: usize) -> f64 {
            if self.steps[trial] == 0 {
                f64::INFINITY
            } else {
                Self::loss_at(self.lrs[trial], self.steps[trial] as f64)
            }
        }
    }

    #[test]
    fn bracket_schedule_shape() {
        let hb = Hyperband { lr_log10_range: (-4.0, 1.0), max_steps_per_trial: 81, eta: 3, seed: 1 };
        let brackets = hb.brackets();
        assert!(!brackets.is_empty());
        // First bracket is the most aggressive (most configs, most rungs).
        assert!(brackets[0].configs >= brackets.last().unwrap().configs);
        assert!(brackets[0].rungs >= brackets.last().unwrap().rungs);
        // Conservative bracket: single rung, one config.
        assert_eq!(brackets.last().unwrap().configs, 1);
    }

    #[test]
    fn finds_good_region_on_synthetic_landscape() {
        let hb = Hyperband { lr_log10_range: (-4.0, 1.0), max_steps_per_trial: 60, eta: 3, seed: 3 };
        let (best, per_bracket) = hb.run(|n| Box::new(SynthRunner::new(n)));
        assert!(!per_bracket.is_empty());
        // Within one decade of the optimum lr=0.1.
        assert!((best.best_lr.log10() + 1.0).abs() < 1.0, "best {}", best.best_lr);
        assert!(best.best_loss.is_finite());
    }

    #[test]
    fn deterministic_per_seed() {
        let hb = Hyperband { lr_log10_range: (-3.0, 0.0), max_steps_per_trial: 27, eta: 3, seed: 9 };
        let (a, _) = hb.run(|n| Box::new(SynthRunner::new(n)));
        let (b, _) = hb.run(|n| Box::new(SynthRunner::new(n)));
        assert_eq!(a.best_lr, b.best_lr);
    }
}
