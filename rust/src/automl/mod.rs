//! AutoML (§3.1): "predict the performance of experiments based on
//! previously run experiments … automatically optimize the hyperparameters
//! based on the performance predictions … save the model of best score."
//!
//! * [`curve`] — learning-curve extrapolation: fit a shifted power law to
//!   a partial loss curve and predict its final value (the "performance
//!   prediction" primitive).
//! * [`search`] — hyperparameter optimization strategies over a
//!   [`TrialRunner`]: grid, random, and successive halving (ASHA-style),
//!   plus prediction-based early termination.

pub mod curve;
pub mod hyperband;
pub mod search;

pub use curve::CurveFit;
pub use hyperband::Hyperband;
pub use search::{log_grid, GridSearch, RandomSearch, SearchOutcome, SuccessiveHalving, TrialRunner};
