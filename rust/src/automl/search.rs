//! Hyperparameter search strategies over an abstract trial runner.
//!
//! The platform implements [`TrialRunner`] with real sessions (each trial
//! is an `nsml run` with a different lr); the unit tests use a synthetic
//! loss landscape so strategy behaviour is verified exactly.

use super::curve::predict_final;
use crate::util::rng::Rng;

/// Runs trials for the searcher. A trial is identified by its index into
/// the searcher's candidate list and can be trained incrementally
/// (supports successive halving's rung promotion).
pub trait TrialRunner {
    /// Train trial `trial` (with hyperparameter `lr`) for `steps` more
    /// steps; returns the observed loss curve points (step, loss) for the
    /// *whole* trial so far.
    fn extend(&mut self, trial: usize, lr: f64, steps: u64) -> Vec<(f64, f64)>;
    /// Final evaluation metric of the trial at its current state (loss;
    /// lower is better).
    fn current_loss(&mut self, trial: usize) -> f64;
    /// Train a whole rung of `(trial, lr, steps)` work items, returning
    /// one curve per item in input order. The default runs them
    /// serially; parallel runners (the platform's executor-pool runner)
    /// override this to train all items concurrently — every strategy
    /// below batches its per-rung work through here.
    fn extend_many(&mut self, work: &[(usize, f64, u64)]) -> Vec<Vec<(f64, f64)>> {
        work.iter().map(|&(trial, lr, steps)| self.extend(trial, lr, steps)).collect()
    }
}

/// Result of a search.
#[derive(Debug, Clone, PartialEq)]
pub struct SearchOutcome {
    pub best_lr: f64,
    pub best_loss: f64,
    pub best_trial: usize,
    /// Total training steps spent across all trials (the budget actually
    /// consumed — the efficiency number the benches compare).
    pub steps_spent: u64,
    /// (lr, final loss or predicted loss, steps given) per candidate.
    pub trials: Vec<(f64, f64, u64)>,
}

/// Log-spaced learning-rate ladder over `[10^lo, 10^hi]` — the shared
/// candidate generator for grid searches, `nsml automl`, and
/// service-level trial batches (`ApiRequest::SubmitTrialBatch`).
pub fn log_grid(candidates: usize, lo_log10: f64, hi_log10: f64) -> Vec<f64> {
    let n = candidates.max(1);
    (0..n)
        .map(|i| 10f64.powf(lo_log10 + (hi_log10 - lo_log10) * i as f64 / (n.max(2) - 1) as f64))
        .collect()
}

/// Exhaustive grid: every candidate gets the full budget. The baseline.
pub struct GridSearch {
    pub lrs: Vec<f64>,
    pub steps_per_trial: u64,
}

impl GridSearch {
    pub fn run(&self, runner: &mut dyn TrialRunner) -> SearchOutcome {
        // The whole grid is one rung: every candidate trains at once on
        // a parallel runner.
        let work: Vec<(usize, f64, u64)> =
            self.lrs.iter().enumerate().map(|(i, &lr)| (i, lr, self.steps_per_trial)).collect();
        runner.extend_many(&work);
        let mut trials = Vec::new();
        let mut spent = 0;
        for (i, &lr) in self.lrs.iter().enumerate() {
            spent += self.steps_per_trial;
            trials.push((lr, runner.current_loss(i), self.steps_per_trial));
        }
        finish(trials, spent)
    }
}

/// Random search with prediction-based early stopping: each candidate
/// trains a probe fraction; its final loss is *predicted* from the curve
/// (§3.1 "predict the performance of experiments"), and only promising
/// ones get the full budget.
pub struct RandomSearch {
    pub candidates: usize,
    pub lr_log10_range: (f64, f64),
    pub steps_per_trial: u64,
    /// Fraction of the budget used for the probe run.
    pub probe_frac: f64,
    pub seed: u64,
}

impl RandomSearch {
    pub fn sample_lrs(&self) -> Vec<f64> {
        let mut rng = Rng::new(self.seed);
        (0..self.candidates)
            .map(|_| 10f64.powf(rng.uniform(self.lr_log10_range.0, self.lr_log10_range.1)))
            .collect()
    }

    pub fn run(&self, runner: &mut dyn TrialRunner) -> SearchOutcome {
        let lrs = self.sample_lrs();
        let probe = ((self.steps_per_trial as f64 * self.probe_frac) as u64).max(3);
        let mut spent = 0;
        // Probe phase: short runs (one parallel rung) + curve prediction.
        let probe_work: Vec<(usize, f64, u64)> =
            lrs.iter().enumerate().map(|(i, &lr)| (i, lr, probe)).collect();
        let curves = runner.extend_many(&probe_work);
        let mut predicted: Vec<(usize, f64)> = Vec::new();
        for (i, curve) in curves.iter().enumerate() {
            spent += probe;
            let pred = predict_final(curve, self.steps_per_trial as f64)
                .unwrap_or_else(|| runner.current_loss(i));
            predicted.push((i, pred));
        }
        predicted.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        // Promote the top third (at least one) to the full budget.
        let promote = (predicted.len() / 3).max(1);
        let mut trials: Vec<(f64, f64, u64)> = lrs.iter().map(|&lr| (lr, f64::NAN, probe)).collect();
        for &(i, pred) in predicted.iter() {
            trials[i].1 = pred;
        }
        let remaining = self.steps_per_trial - probe;
        let promote_work: Vec<(usize, f64, u64)> =
            predicted.iter().take(promote).map(|&(i, _)| (i, lrs[i], remaining)).collect();
        runner.extend_many(&promote_work);
        for &(i, _, _) in &promote_work {
            spent += remaining;
            trials[i] = (lrs[i], runner.current_loss(i), self.steps_per_trial);
        }
        finish(trials, spent)
    }
}

/// Successive halving (ASHA-style): rungs of increasing budget, keeping
/// the best `1/eta` fraction at each rung.
pub struct SuccessiveHalving {
    pub lrs: Vec<f64>,
    pub total_steps_per_trial: u64,
    pub eta: usize,
    pub rungs: usize,
}

impl SuccessiveHalving {
    pub fn run(&self, runner: &mut dyn TrialRunner) -> SearchOutcome {
        assert!(self.eta >= 2 && self.rungs >= 1);
        // Budget per rung grows geometrically to sum to the full budget.
        let denom: f64 = (0..self.rungs).map(|r| (self.eta as f64).powi(r as i32)).sum();
        let base = (self.total_steps_per_trial as f64 / denom).max(1.0);
        let mut alive: Vec<usize> = (0..self.lrs.len()).collect();
        let mut given = vec![0u64; self.lrs.len()];
        let mut spent = 0;
        for rung in 0..self.rungs {
            let steps = (base * (self.eta as f64).powi(rung as i32)).round() as u64;
            // All survivors of the rung train together (parallel on a
            // pool-backed runner), then get scored.
            let work: Vec<(usize, f64, u64)> = alive.iter().map(|&i| (i, self.lrs[i], steps)).collect();
            runner.extend_many(&work);
            let mut scored: Vec<(usize, f64)> = Vec::new();
            for &i in &alive {
                given[i] += steps;
                spent += steps;
                scored.push((i, runner.current_loss(i)));
            }
            scored.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let keep = (scored.len() / self.eta).max(1);
            alive = scored.iter().take(keep).map(|&(i, _)| i).collect();
            if alive.len() == 1 && rung + 1 < self.rungs {
                // Sole survivor gets the remaining rung budgets at once.
                let remaining: u64 = (rung + 1..self.rungs)
                    .map(|r| (base * (self.eta as f64).powi(r as i32)).round() as u64)
                    .sum();
                if remaining > 0 {
                    let i = alive[0];
                    runner.extend(i, self.lrs[i], remaining);
                    given[i] += remaining;
                    spent += remaining;
                }
                break;
            }
        }
        let trials: Vec<(f64, f64, u64)> = self
            .lrs
            .iter()
            .enumerate()
            .map(|(i, &lr)| (lr, runner.current_loss(i), given[i]))
            .collect();
        finish(trials, spent)
    }
}

fn finish(trials: Vec<(f64, f64, u64)>, steps_spent: u64) -> SearchOutcome {
    let (best_trial, &(best_lr, best_loss, _)) = trials
        .iter()
        .enumerate()
        .filter(|(_, t)| t.1.is_finite())
        .min_by(|a, b| a.1 .1.partial_cmp(&b.1 .1).unwrap())
        .expect("at least one finished trial");
    SearchOutcome { best_lr, best_loss, best_trial, steps_spent, trials }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic landscape: loss(lr, t) follows a power law whose
    /// asymptote is quadratic in log10(lr) with optimum at lr = 0.1.
    struct SynthRunner {
        curves: Vec<Vec<(f64, f64)>>,
        steps: Vec<u64>,
        lrs: Vec<f64>,
    }

    impl SynthRunner {
        fn new(n: usize) -> SynthRunner {
            SynthRunner { curves: vec![Vec::new(); n], steps: vec![0; n], lrs: vec![f64::NAN; n] }
        }

        fn loss_at(lr: f64, t: f64) -> f64 {
            let opt = (lr.log10() + 1.0).abs(); // optimum at 0.1
            let asymptote = 0.2 + opt * opt;
            asymptote + 2.0 * (t + 1.0).powf(-0.6)
        }
    }

    impl TrialRunner for SynthRunner {
        fn extend(&mut self, trial: usize, lr: f64, steps: u64) -> Vec<(f64, f64)> {
            self.lrs[trial] = lr;
            for _ in 0..steps {
                self.steps[trial] += 1;
                let t = self.steps[trial] as f64;
                self.curves[trial].push((t, Self::loss_at(lr, t)));
            }
            self.curves[trial].clone()
        }

        fn current_loss(&mut self, trial: usize) -> f64 {
            if self.steps[trial] == 0 {
                return f64::INFINITY;
            }
            Self::loss_at(self.lrs[trial], self.steps[trial] as f64)
        }
    }

    const GRID: [f64; 6] = [0.0003, 0.003, 0.03, 0.1, 0.3, 3.0];

    #[test]
    fn grid_finds_optimum_with_full_budget() {
        let mut runner = SynthRunner::new(GRID.len());
        let out = GridSearch { lrs: GRID.to_vec(), steps_per_trial: 100 }.run(&mut runner);
        assert!((out.best_lr - 0.1).abs() < 1e-9);
        assert_eq!(out.steps_spent, 600);
        assert_eq!(out.trials.len(), 6);
    }

    #[test]
    fn successive_halving_finds_optimum_cheaper() {
        let mut grid_runner = SynthRunner::new(GRID.len());
        let grid = GridSearch { lrs: GRID.to_vec(), steps_per_trial: 100 }.run(&mut grid_runner);

        let mut sh_runner = SynthRunner::new(GRID.len());
        let sh = SuccessiveHalving { lrs: GRID.to_vec(), total_steps_per_trial: 100, eta: 2, rungs: 3 }
            .run(&mut sh_runner);
        assert!((sh.best_lr - 0.1).abs() < 1e-9, "best {}", sh.best_lr);
        assert!(sh.steps_spent < grid.steps_spent / 2, "{} vs {}", sh.steps_spent, grid.steps_spent);
    }

    #[test]
    fn random_search_probe_promotes_good_region() {
        let rs = RandomSearch {
            candidates: 12,
            lr_log10_range: (-4.0, 1.0),
            steps_per_trial: 90,
            probe_frac: 0.1,
            seed: 5,
        };
        let mut runner = SynthRunner::new(rs.candidates);
        let out = rs.run(&mut runner);
        // Best found lr is within an order of magnitude of the optimum.
        assert!((out.best_lr.log10() + 1.0).abs() < 1.0, "best {}", out.best_lr);
        // Early stopping really saves budget vs full-budget-on-everything.
        assert!(out.steps_spent < 12 * 90, "spent {}", out.steps_spent);
        // Full budget went to at least one candidate.
        assert!(out.trials.iter().any(|t| t.2 == 90));
    }

    #[test]
    fn log_grid_spans_range() {
        let g = log_grid(6, -3.5, 0.5);
        assert_eq!(g.len(), 6);
        assert!((g[0] - 10f64.powf(-3.5)).abs() < 1e-12);
        assert!((g[5] - 10f64.powf(0.5)).abs() < 1e-9);
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        let single = log_grid(1, -2.0, 0.0);
        assert_eq!(single.len(), 1);
        assert!((single[0] - 0.01).abs() < 1e-12);
    }

    #[test]
    fn sample_lrs_deterministic() {
        let rs = RandomSearch {
            candidates: 5,
            lr_log10_range: (-3.0, 0.0),
            steps_per_trial: 10,
            probe_frac: 0.3,
            seed: 7,
        };
        assert_eq!(rs.sample_lrs(), rs.sample_lrs());
        assert!(rs.sample_lrs().iter().all(|&lr| (1e-3..=1.0).contains(&lr)));
    }

    #[test]
    fn sole_survivor_gets_remaining_budget() {
        let lrs = vec![0.1, 3.0];
        let mut runner = SynthRunner::new(2);
        let out = SuccessiveHalving { lrs, total_steps_per_trial: 70, eta: 2, rungs: 3 }.run(&mut runner);
        assert_eq!(out.best_trial, 0);
        // Winner consumed (close to) its full per-trial budget.
        assert!(out.trials[0].2 >= 60, "{:?}", out.trials);
        // Loser stopped at the first rung.
        assert!(out.trials[1].2 <= 15, "{:?}", out.trials);
    }
}
