//! Containerized ML system (paper §3.2/§3.3) — the Docker stand-in.
//!
//! "When a user sets up an environment, NSML automatically packages it
//! into a ML container and copies the user's codes and datasets from the
//! respective storage containers."
//!
//! Docker is unavailable offline, so this module models the container
//! substrate with the granularity the paper's claims need:
//!
//! * [`ImageCache`] — §3.3 bottleneck 1: "We removed the first bottleneck
//!   by *reusing existing docker images* if a user needs the same
//!   environment." Cold builds pay a build latency; cache hits are nearly
//!   free. (Experiment E7.)
//! * [`MountTable`] — §3.3 bottleneck 2: "solved by *sharing dataset
//!   directories* among all ML containers when they are physically
//!   located at the same host machine." First mount on a host copies the
//!   dataset; subsequent mounts bind-share it. (Experiment E8.)
//! * [`ContainerManager`] — the ML-container lifecycle FSM wiring both
//!   together; per-container isolation lets different sessions use
//!   different frameworks on the same node, like the paper's
//!   PyTorch-py27 / TF-py36 example.
//!
//! All latencies come from a configurable [`LatencyModel`] and advance the
//! platform [`Clock`](crate::util::clock::Clock) (virtual in tests/benches,
//! real in live runs), so the cold/warm asymmetries are measurable without
//! real Docker.

mod image;
mod mount;
mod lifecycle;

pub use image::{BuildOutcome, ImageCache, ImageId, ImageSpec};
pub use lifecycle::{Container, ContainerManager, ContainerState};
pub use mount::{MountOutcome, MountTable};

use crate::util::clock::Millis;

/// Latency model for container operations (defaults approximate the real
/// Docker numbers the paper's deployment would see).
#[derive(Debug, Clone)]
pub struct LatencyModel {
    /// Building an image from a base + environment spec (cold).
    pub image_build_ms: Millis,
    /// Reusing a cached image (warm).
    pub image_reuse_ms: Millis,
    /// Copying a dataset onto a host, per GB.
    pub dataset_copy_ms_per_gb: Millis,
    /// Bind-mounting an already-present dataset directory.
    pub dataset_share_ms: Millis,
    /// Container create + boot once image and data are ready.
    pub boot_ms: Millis,
}

impl Default for LatencyModel {
    fn default() -> LatencyModel {
        LatencyModel {
            image_build_ms: 45_000,        // docker build of a DL env
            image_reuse_ms: 400,           // image inspect + create
            dataset_copy_ms_per_gb: 9_000, // ~110 MB/s effective copy
            dataset_share_ms: 40,          // bind mount
            boot_ms: 1_200,                // container start + runtime init
        }
    }
}

impl LatencyModel {
    /// A fast model for unit tests (same ratios, 1000× smaller).
    pub fn fast() -> LatencyModel {
        LatencyModel {
            image_build_ms: 45,
            image_reuse_ms: 1,
            dataset_copy_ms_per_gb: 9,
            dataset_share_ms: 1,
            boot_ms: 2,
        }
    }
}
