//! ML-container lifecycle: what happens on a node between "scheduler
//! placed the job here" and "user code is running".
//!
//! NSML's startup sequence (§3.3): ensure the docker image (build or
//! reuse), make the dataset available (copy or host-share), boot the
//! container, then hand control to the session runner.

use super::image::{BuildOutcome, ImageCache, ImageId, ImageSpec};
use super::mount::{MountOutcome, MountTable};
use super::LatencyModel;
use crate::cluster::NodeId;
use crate::events::EventLog;
use crate::util::clock::{Millis, SharedClock};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// Container FSM states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerState {
    Preparing,
    Running,
    Stopped,
}

/// A launched ML container.
#[derive(Debug, Clone)]
pub struct Container {
    pub id: String,
    pub job: String,
    pub node: NodeId,
    pub image: ImageId,
    pub dataset: String,
    pub state: ContainerState,
    /// Total startup latency the job paid before running.
    pub startup_ms: Millis,
    pub image_outcome: BuildOutcome,
    pub mount_outcome: MountOutcome,
}

/// Launch + teardown of ML containers across the cluster.
#[derive(Clone)]
pub struct ContainerManager {
    clock: SharedClock,
    images: ImageCache,
    mounts: MountTable,
    latency: LatencyModel,
    events: EventLog,
    inner: Arc<Mutex<BTreeMap<String, Container>>>,
}

impl ContainerManager {
    pub fn new(clock: SharedClock, events: EventLog, latency: LatencyModel) -> ContainerManager {
        ContainerManager {
            images: ImageCache::new(clock.clone(), latency.clone()),
            mounts: MountTable::new(clock.clone(), latency.clone()),
            clock,
            latency,
            events,
            inner: Arc::new(Mutex::new(BTreeMap::new())),
        }
    }

    /// Bring up a container for `job` on `node`: image + dataset + boot.
    /// Returns the running container; the clock has advanced by its
    /// startup latency.
    pub fn launch(
        &self,
        job: &str,
        node: NodeId,
        spec: &ImageSpec,
        dataset: &str,
        dataset_size_gb: f64,
    ) -> Container {
        let t0 = self.clock.now_ms();
        let (image, image_outcome, image_ms) = self.images.ensure(spec);
        let (mount_outcome, mount_ms) = self.mounts.mount(node, dataset, dataset_size_gb);
        self.clock.sleep_ms(self.latency.boot_ms);
        let startup_ms = self.clock.now_ms().saturating_sub(t0);
        let container = Container {
            id: format!("ctr-{}-{}", node.0, job),
            job: job.to_string(),
            node,
            image,
            dataset: dataset.to_string(),
            state: ContainerState::Running,
            startup_ms,
            image_outcome,
            mount_outcome,
        };
        self.events.info(
            "container",
            job,
            format!(
                "container up on {} in {} ms (image {:?} {} ms, dataset {:?} {} ms)",
                node, startup_ms, image_outcome, image_ms, mount_outcome, mount_ms
            ),
        );
        self.inner.lock().unwrap().insert(container.id.clone(), container.clone());
        container
    }

    /// Stop a job's container and release its dataset reference.
    pub fn stop(&self, container_id: &str) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if let Some(c) = inner.get_mut(container_id) {
            if c.state == ContainerState::Running {
                c.state = ContainerState::Stopped;
                self.mounts.unmount(c.node, &c.dataset);
                self.events.info("container", &c.job.clone(), "container stopped");
                return true;
            }
        }
        false
    }

    /// Stop whatever container is running `job`.
    pub fn stop_job(&self, job: &str) -> bool {
        let id = {
            let inner = self.inner.lock().unwrap();
            inner.values().find(|c| c.job == job && c.state == ContainerState::Running).map(|c| c.id.clone())
        };
        id.map(|id| self.stop(&id)).unwrap_or(false)
    }

    pub fn get(&self, container_id: &str) -> Option<Container> {
        self.inner.lock().unwrap().get(container_id).cloned()
    }

    pub fn running(&self) -> Vec<Container> {
        self.inner.lock().unwrap().values().filter(|c| c.state == ContainerState::Running).cloned().collect()
    }

    pub fn images(&self) -> &ImageCache {
        &self.images
    }

    pub fn mounts(&self) -> &MountTable {
        &self.mounts
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn mgr() -> (ContainerManager, SharedClock) {
        let (clock, _) = sim_clock();
        let events = EventLog::new(clock.clone()).with_echo(false);
        (ContainerManager::new(clock.clone(), events, LatencyModel::fast()), clock)
    }

    #[test]
    fn cold_start_pays_build_and_copy() {
        let (m, clock) = mgr();
        let c = m.launch("job-1", NodeId(0), &ImageSpec::tensorflow(), "mnist", 2.0);
        assert_eq!(c.state, ContainerState::Running);
        assert_eq!(c.image_outcome, BuildOutcome::Built);
        assert_eq!(c.mount_outcome, MountOutcome::Copied);
        // 45 (build) + 18 (copy 2GB) + 2 (boot) with the fast model.
        assert_eq!(c.startup_ms, 65);
        assert_eq!(clock.now_ms(), 65);
    }

    #[test]
    fn warm_start_is_much_cheaper() {
        let (m, _) = mgr();
        m.launch("a", NodeId(0), &ImageSpec::tensorflow(), "mnist", 2.0);
        let c = m.launch("b", NodeId(0), &ImageSpec::tensorflow(), "mnist", 2.0);
        assert_eq!(c.image_outcome, BuildOutcome::Reused);
        assert_eq!(c.mount_outcome, MountOutcome::Shared);
        // 1 (reuse) + 1 (share) + 2 (boot).
        assert_eq!(c.startup_ms, 4);
    }

    #[test]
    fn same_image_other_node_still_copies_dataset() {
        let (m, _) = mgr();
        m.launch("a", NodeId(0), &ImageSpec::tensorflow(), "mnist", 1.0);
        let c = m.launch("b", NodeId(1), &ImageSpec::tensorflow(), "mnist", 1.0);
        // Image cache is registry-wide; dataset copies are per host.
        assert_eq!(c.image_outcome, BuildOutcome::Reused);
        assert_eq!(c.mount_outcome, MountOutcome::Copied);
    }

    #[test]
    fn stop_releases_mount_ref() {
        let (m, _) = mgr();
        let c = m.launch("a", NodeId(0), &ImageSpec::pytorch(), "d", 1.0);
        assert_eq!(m.mounts().refcount(NodeId(0), "d"), 1);
        assert!(m.stop(&c.id));
        assert!(!m.stop(&c.id)); // idempotent
        assert_eq!(m.mounts().refcount(NodeId(0), "d"), 0);
        assert!(m.running().is_empty());
    }

    #[test]
    fn stop_by_job_name() {
        let (m, _) = mgr();
        m.launch("target", NodeId(1), &ImageSpec::pytorch(), "d", 0.5);
        assert!(m.stop_job("target"));
        assert!(!m.stop_job("target"));
        assert!(!m.stop_job("missing"));
    }

    #[test]
    fn mixed_frameworks_coexist_on_one_node() {
        // The paper's PyTorch-py27 vs TF-py36 isolation example.
        let (m, _) = mgr();
        let a = m.launch("py27", NodeId(0), &ImageSpec::new("cuda", "torch", "2.7", &[]), "d", 0.1);
        let b = m.launch("py36", NodeId(0), &ImageSpec::new("cuda", "tf", "3.6", &[]), "d", 0.1);
        assert_ne!(a.image, b.image);
        assert_eq!(m.running().len(), 2);
        assert_eq!(m.images().cached_count(), 2);
    }
}
