//! Docker-image cache with reuse (paper §3.3, bottleneck 1).

use super::LatencyModel;
use crate::util::clock::{Millis, SharedClock};
use sha2::{Digest, Sha256};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// An ML environment: what the user's docker image is built from.
/// "If one user wants to use PyTorch in python 2.7, he or she just needs
/// to select the corresponding base docker image" (§3.2).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ImageSpec {
    pub base: String,
    pub framework: String,
    pub python: String,
    /// Extra pip requirements, order-insensitive.
    pub pip: Vec<String>,
}

impl ImageSpec {
    pub fn new(base: &str, framework: &str, python: &str, pip: &[&str]) -> ImageSpec {
        let mut pip: Vec<String> = pip.iter().map(|s| s.to_string()).collect();
        pip.sort();
        ImageSpec {
            base: base.to_string(),
            framework: framework.to_string(),
            python: python.to_string(),
            pip,
        }
    }

    /// Canonical digest of the environment (the cache key).
    pub fn digest(&self) -> ImageId {
        let mut h = Sha256::new();
        h.update(self.base.as_bytes());
        h.update([0]);
        h.update(self.framework.as_bytes());
        h.update([0]);
        h.update(self.python.as_bytes());
        for p in &self.pip {
            h.update([0]);
            h.update(p.as_bytes());
        }
        let out = h.finalize();
        ImageId(out.iter().take(16).map(|b| format!("{:02x}", b)).collect())
    }

    /// The default TF image NSML docs use in examples.
    pub fn tensorflow() -> ImageSpec {
        ImageSpec::new("nvidia/cuda:9.0", "tensorflow==1.4", "3.6", &[])
    }

    pub fn pytorch() -> ImageSpec {
        ImageSpec::new("nvidia/cuda:9.0", "torch==0.3", "3.6", &[])
    }
}

/// Image identifier (truncated digest, like a docker image id).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ImageId(pub String);

impl std::fmt::Display for ImageId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0[..12.min(self.0.len())])
    }
}

/// How an image was obtained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuildOutcome {
    /// Cold: full build, expensive.
    Built,
    /// Warm: cache hit, cheap.
    Reused,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct ImageStats {
    pub builds: u64,
    pub reuses: u64,
    pub build_ms_total: Millis,
}

/// Cluster-wide image cache (the paper shares built images per registry).
#[derive(Clone)]
pub struct ImageCache {
    clock: SharedClock,
    latency: LatencyModel,
    inner: Arc<Mutex<CacheState>>,
}

struct CacheState {
    images: BTreeMap<ImageId, ImageSpec>,
    stats: ImageStats,
    enabled: bool,
}

impl ImageCache {
    pub fn new(clock: SharedClock, latency: LatencyModel) -> ImageCache {
        ImageCache {
            clock,
            latency,
            inner: Arc::new(Mutex::new(CacheState {
                images: BTreeMap::new(),
                stats: ImageStats::default(),
                enabled: true,
            })),
        }
    }

    /// Ablation switch (E7): disable reuse so every ensure() builds.
    pub fn set_enabled(&self, enabled: bool) {
        self.inner.lock().unwrap().enabled = enabled;
    }

    /// Ensure an image for `spec` exists; returns (id, outcome, cost_ms).
    /// Advances the platform clock by the operation's latency.
    pub fn ensure(&self, spec: &ImageSpec) -> (ImageId, BuildOutcome, Millis) {
        let id = spec.digest();
        let (outcome, cost) = {
            let mut st = self.inner.lock().unwrap();
            if st.enabled && st.images.contains_key(&id) {
                st.stats.reuses += 1;
                (BuildOutcome::Reused, self.latency.image_reuse_ms)
            } else {
                st.images.insert(id.clone(), spec.clone());
                st.stats.builds += 1;
                st.stats.build_ms_total += self.latency.image_build_ms;
                (BuildOutcome::Built, self.latency.image_build_ms)
            }
        };
        self.clock.sleep_ms(cost);
        (id, outcome, cost)
    }

    pub fn stats(&self) -> ImageStats {
        self.inner.lock().unwrap().stats
    }

    pub fn cached_count(&self) -> usize {
        self.inner.lock().unwrap().images.len()
    }

    /// Drop every cached image (e.g. registry GC).
    pub fn clear(&self) {
        self.inner.lock().unwrap().images.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn cache() -> (ImageCache, crate::util::clock::SimClock) {
        let (clock, sim) = sim_clock();
        (ImageCache::new(clock, LatencyModel::fast()), sim)
    }

    #[test]
    fn digest_stable_and_order_insensitive() {
        let a = ImageSpec::new("cuda", "tf", "3.6", &["numpy", "scipy"]);
        let b = ImageSpec::new("cuda", "tf", "3.6", &["scipy", "numpy"]);
        assert_eq!(a.digest(), b.digest());
        let c = ImageSpec::new("cuda", "tf", "2.7", &["numpy", "scipy"]);
        assert_ne!(a.digest(), c.digest());
    }

    #[test]
    fn first_build_cold_then_warm() {
        let (cache, sim) = cache();
        let spec = ImageSpec::tensorflow();
        let (id1, o1, c1) = cache.ensure(&spec);
        assert_eq!(o1, BuildOutcome::Built);
        assert_eq!(c1, 45);
        let (id2, o2, c2) = cache.ensure(&spec);
        assert_eq!(o2, BuildOutcome::Reused);
        assert_eq!(c2, 1);
        assert_eq!(id1, id2);
        let _ = sim;
    }

    #[test]
    fn clock_advances_by_cost() {
        let (clock, _sim) = sim_clock();
        let cache = ImageCache::new(clock.clone(), LatencyModel::fast());
        cache.ensure(&ImageSpec::tensorflow());
        assert_eq!(clock.now_ms(), 45);
        cache.ensure(&ImageSpec::tensorflow());
        assert_eq!(clock.now_ms(), 46);
    }

    #[test]
    fn different_envs_do_not_share() {
        let (cache, _) = cache();
        let (_, o1, _) = cache.ensure(&ImageSpec::tensorflow());
        let (_, o2, _) = cache.ensure(&ImageSpec::pytorch());
        assert_eq!(o1, BuildOutcome::Built);
        assert_eq!(o2, BuildOutcome::Built);
        assert_eq!(cache.cached_count(), 2);
    }

    #[test]
    fn disabled_cache_always_builds() {
        let (cache, _) = cache();
        cache.set_enabled(false);
        cache.ensure(&ImageSpec::tensorflow());
        let (_, o, _) = cache.ensure(&ImageSpec::tensorflow());
        assert_eq!(o, BuildOutcome::Built);
        assert_eq!(cache.stats().builds, 2);
        assert_eq!(cache.stats().reuses, 0);
    }

    #[test]
    fn stats_track() {
        let (cache, _) = cache();
        for _ in 0..3 {
            cache.ensure(&ImageSpec::tensorflow());
        }
        let s = cache.stats();
        assert_eq!(s.builds, 1);
        assert_eq!(s.reuses, 2);
        assert_eq!(s.build_ms_total, 45);
    }
}
