//! Host-shared dataset mounts (paper §3.3, bottleneck 2).
//!
//! First container that needs a dataset on a host pays the copy from the
//! storage container; later containers on the same host bind-share the
//! directory. Reference counts track when a host copy becomes garbage.

use super::LatencyModel;
use crate::cluster::NodeId;
use crate::util::clock::{Millis, SharedClock};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// How a dataset was made available to a container.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MountOutcome {
    /// First use on this host: full copy from storage.
    Copied,
    /// Host already has it: bind mount.
    Shared,
}

#[derive(Debug, Default, Clone, Copy, PartialEq)]
pub struct MountStats {
    pub copies: u64,
    pub shares: u64,
    pub copy_ms_total: Millis,
    pub bytes_copied_gb: f64,
}

/// Cluster-wide mount bookkeeping.
#[derive(Clone)]
pub struct MountTable {
    clock: SharedClock,
    latency: LatencyModel,
    inner: Arc<Mutex<TableState>>,
}

struct TableState {
    /// (node, dataset) -> refcount.
    mounts: BTreeMap<(NodeId, String), u32>,
    stats: MountStats,
    sharing_enabled: bool,
}

impl MountTable {
    pub fn new(clock: SharedClock, latency: LatencyModel) -> MountTable {
        MountTable {
            clock,
            latency,
            inner: Arc::new(Mutex::new(TableState {
                mounts: BTreeMap::new(),
                stats: MountStats::default(),
                sharing_enabled: true,
            })),
        }
    }

    /// Ablation switch (E8): disable sharing so every mount copies.
    pub fn set_sharing(&self, enabled: bool) {
        self.inner.lock().unwrap().sharing_enabled = enabled;
    }

    /// Mount `dataset` (of `size_gb`) for one container on `node`.
    /// Advances the clock by the operation's latency.
    pub fn mount(&self, node: NodeId, dataset: &str, size_gb: f64) -> (MountOutcome, Millis) {
        let key = (node, dataset.to_string());
        let (outcome, cost) = {
            let mut st = self.inner.lock().unwrap();
            // A host copy stays warm at refcount 0 until gc() evicts it.
            let present = st.mounts.contains_key(&key);
            if present && st.sharing_enabled {
                *st.mounts.get_mut(&key).unwrap() += 1;
                st.stats.shares += 1;
                (MountOutcome::Shared, self.latency.dataset_share_ms)
            } else {
                *st.mounts.entry(key).or_insert(0) += 1;
                let cost = (self.latency.dataset_copy_ms_per_gb as f64 * size_gb).ceil() as Millis;
                st.stats.copies += 1;
                st.stats.copy_ms_total += cost;
                st.stats.bytes_copied_gb += size_gb;
                (MountOutcome::Copied, cost)
            }
        };
        self.clock.sleep_ms(cost);
        (outcome, cost)
    }

    /// Release one container's reference.
    pub fn unmount(&self, node: NodeId, dataset: &str) {
        let mut st = self.inner.lock().unwrap();
        if let Some(rc) = st.mounts.get_mut(&(node, dataset.to_string())) {
            *rc = rc.saturating_sub(1);
        }
    }

    /// Hosts where the dataset is currently resident (refcount > 0 keeps
    /// the copy; refcount 0 is eligible for GC but still cached until
    /// [`gc`](Self::gc) runs — matching how hosts keep directories warm).
    pub fn resident_nodes(&self, dataset: &str) -> Vec<NodeId> {
        self.inner
            .lock()
            .unwrap()
            .mounts
            .keys()
            .filter(|(_, d)| d == dataset)
            .map(|(n, _)| *n)
            .collect()
    }

    pub fn refcount(&self, node: NodeId, dataset: &str) -> u32 {
        self.inner.lock().unwrap().mounts.get(&(node, dataset.to_string())).copied().unwrap_or(0)
    }

    /// Evict zero-refcount host copies; returns how many were dropped.
    pub fn gc(&self) -> usize {
        let mut st = self.inner.lock().unwrap();
        let before = st.mounts.len();
        st.mounts.retain(|_, rc| *rc > 0);
        before - st.mounts.len()
    }

    pub fn stats(&self) -> MountStats {
        self.inner.lock().unwrap().stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::sim_clock;

    fn table() -> (MountTable, crate::util::clock::SharedClock) {
        let (clock, _) = sim_clock();
        (MountTable::new(clock.clone(), LatencyModel::fast()), clock)
    }

    #[test]
    fn first_copy_then_share() {
        let (t, clock) = table();
        let (o1, c1) = t.mount(NodeId(0), "mnist", 2.0);
        assert_eq!(o1, MountOutcome::Copied);
        assert_eq!(c1, 18); // 9 ms/GB × 2 GB
        let (o2, c2) = t.mount(NodeId(0), "mnist", 2.0);
        assert_eq!(o2, MountOutcome::Shared);
        assert_eq!(c2, 1);
        assert_eq!(clock.now_ms(), 19);
        assert_eq!(t.refcount(NodeId(0), "mnist"), 2);
    }

    #[test]
    fn different_hosts_copy_independently() {
        let (t, _) = table();
        t.mount(NodeId(0), "d", 1.0);
        let (o, _) = t.mount(NodeId(1), "d", 1.0);
        assert_eq!(o, MountOutcome::Copied);
        assert_eq!(t.resident_nodes("d").len(), 2);
    }

    #[test]
    fn sharing_disabled_always_copies() {
        let (t, _) = table();
        t.set_sharing(false);
        t.mount(NodeId(0), "d", 1.0);
        let (o, _) = t.mount(NodeId(0), "d", 1.0);
        assert_eq!(o, MountOutcome::Copied);
        assert_eq!(t.stats().copies, 2);
    }

    #[test]
    fn unmount_and_gc() {
        let (t, _) = table();
        t.mount(NodeId(0), "d", 1.0);
        t.mount(NodeId(0), "d", 1.0);
        t.unmount(NodeId(0), "d");
        // Still resident (one ref + warm cache semantics).
        assert_eq!(t.refcount(NodeId(0), "d"), 1);
        assert_eq!(t.gc(), 0);
        t.unmount(NodeId(0), "d");
        assert_eq!(t.gc(), 1);
        // After GC the next mount copies again.
        let (o, _) = t.mount(NodeId(0), "d", 1.0);
        assert_eq!(o, MountOutcome::Copied);
    }

    #[test]
    fn warm_cache_survives_zero_refcount_until_gc() {
        let (t, _) = table();
        t.mount(NodeId(0), "d", 1.0);
        t.unmount(NodeId(0), "d");
        // No GC yet: mounting shares the warm copy.
        let (o, _) = t.mount(NodeId(0), "d", 1.0);
        assert_eq!(o, MountOutcome::Shared);
    }

    #[test]
    fn stats_accumulate() {
        let (t, _) = table();
        t.mount(NodeId(0), "a", 1.0);
        t.mount(NodeId(1), "a", 1.0);
        t.mount(NodeId(0), "a", 1.0);
        let s = t.stats();
        assert_eq!(s.copies, 2);
        assert_eq!(s.shares, 1);
        assert!((s.bytes_copied_gb - 2.0).abs() < 1e-9);
    }
}
