//! Multi-tenant fair share, end to end: quota enforcement at
//! admission, the preempt → re-admit → resume round trip, fair
//! interleaving of two users' submissions, and the tenancy wire/web
//! surfaces (`tenant_report`, `set_quota`, board user filter).

use nsml::api::{ApiRequest, ApiResponse, NsmlPlatform, PlatformConfig, PlatformService, RunOpts};
use nsml::events::{EventFilter, EventKind};
use nsml::session::SessionState;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(dir)
}

fn platform_with(nodes: usize, gpus_per_node: usize) -> Option<NsmlPlatform> {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = artifacts()?;
    cfg.nodes = nodes;
    cfg.gpus_per_node = gpus_per_node;
    Some(NsmlPlatform::new(cfg).unwrap())
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 2).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

/// Admission decisions (`admit`/`readmit`/`defer`/`preempt`) for a
/// subject, in publish order.
fn decisions_for(p: &NsmlPlatform, subject: &str) -> Vec<String> {
    p.events
        .bus()
        .read_since(0, 0, &EventFilter::default().with_kind("admission").with_subject(subject))
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::AdmissionDecided { decision, .. } => Some(decision.clone()),
            _ => None,
        })
        .collect()
}

#[test]
fn concurrency_quota_defers_until_capacity_frees() {
    let Some(p) = platform_with(3, 4) else { return };
    p.tenancy.registry.update_quota("lim", |q| q.max_concurrent = 1);
    let a = p.run("lim", "mnist", quick(16, 0)).unwrap();
    let b = p.run("lim", "mnist", quick(16, 1)).unwrap();
    // Plenty of free GPUs, but the quota holds b back.
    assert!(p.sessions.get(&a).unwrap().node.is_some());
    assert_eq!(p.sessions.get(&b).unwrap().node, None);
    assert_eq!(p.tenancy.admission.depth_of("lim"), 1);
    assert_eq!(decisions_for(&p, &a), vec!["admit"]);
    assert_eq!(decisions_for(&p, &b), vec!["defer"]);

    p.run_to_completion(8, 10_000).unwrap();
    for id in [&a, &b] {
        assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
    }
    // b was admitted once a's slot freed.
    assert_eq!(decisions_for(&p, &b), vec!["defer", "admit"]);
    assert_eq!(p.tenancy.registry.occupancy("lim"), (0, 0), "charges credited back");
    // The accountant billed real GPU-seconds for both sessions.
    assert!(p.tenancy.accountant.usage_at("lim", p.clock.now_ms()) > 0.0);
}

#[test]
fn gpu_quota_caps_parallel_holdings() {
    let Some(p) = platform_with(3, 4) else { return };
    p.tenancy.registry.update_quota("gq", |q| q.max_gpus = 2);
    let mut two = quick(16, 0);
    two.gpus = 2;
    let a = p.run("gq", "mnist", two.clone()).unwrap();
    two.seed = 1;
    let b = p.run("gq", "mnist", two).unwrap();
    // 12 GPUs free, but the user may only hold 2 at once.
    assert!(p.sessions.get(&a).unwrap().node.is_some());
    assert_eq!(p.sessions.get(&b).unwrap().node, None);
    p.run_to_completion(8, 10_000).unwrap();
    assert_eq!(p.sessions.get(&b).unwrap().state, SessionState::Done);
}

#[test]
fn budget_preemption_pauses_and_resumes_from_checkpoint() {
    // Single-GPU pool: the budget hog must yield for the second user.
    let Some(p) = platform_with(1, 1) else { return };
    p.tenancy.registry.update_quota("hog", |q| q.gpu_second_budget = 0.001);
    // Long enough that it cannot finish before the preemption round.
    let a = p
        .run(
            "hog",
            "mnist",
            RunOpts { total_steps: 200, checkpoint_every: 50, eval_every: 100, ..Default::default() },
        )
        .unwrap();
    // Train a few rounds; virtual time accrues GPU-seconds well past
    // the 1ms budget.
    for _ in 0..3 {
        p.drive_round(10).unwrap();
    }
    assert!(p.tenancy.accountant.usage_at("hog", p.clock.now_ms()) > 0.001);
    assert_eq!(p.sessions.get(&a).unwrap().state, SessionState::Running);

    // Another user arrives; the pool is saturated, so they wait.
    let b = p.run("fair", "mnist", quick(16, 1)).unwrap();
    assert_eq!(p.sessions.get(&b).unwrap().node, None);

    // The next drive round preempts the hog's session for them.
    p.drive_round(10).unwrap();
    let rec = p.sessions.get(&a).unwrap();
    assert_eq!(rec.preemptions, 1, "one preemption recorded");
    assert!(decisions_for(&p, &a).contains(&"preempt".to_string()));
    assert!(p.sessions.get(&b).unwrap().node.is_some(), "waiting user got the GPU");

    // Everything still finishes: b runs now, a re-admits afterwards
    // and resumes from its preemption checkpoint.
    p.run_to_completion(10, 10_000).unwrap();
    let rec = p.sessions.get(&a).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 200, "resumed, not restarted");
    assert_eq!(rec.recoveries, 0, "preemption is not a failure recovery");
    assert_eq!(rec.preemptions, 1);
    assert!(!rec.preempted);
    assert!(decisions_for(&p, &a).contains(&"readmit".to_string()));
    assert_eq!(p.sessions.get(&b).unwrap().state, SessionState::Done);
}

#[test]
fn quota_blocked_waiter_does_not_trigger_preemption() {
    // An over-budget user must only yield when the waiter could
    // actually use the freed capacity — a waiter blocked by their OWN
    // quota (max_concurrent here) must not cause eviction thrash.
    let Some(p) = platform_with(1, 2) else { return };
    p.tenancy.registry.update_quota("hog", |q| q.gpu_second_budget = 0.001);
    p.tenancy.registry.update_quota("capped", |q| q.max_concurrent = 1);
    let hog = p
        .run(
            "hog",
            "mnist",
            RunOpts { total_steps: 200, checkpoint_every: 50, eval_every: 100, ..Default::default() },
        )
        .unwrap();
    // Long enough to still be running when the second submission lands.
    let c1 = p.run("capped", "mnist", quick(200, 1)).unwrap();
    for _ in 0..3 {
        p.drive_round(10).unwrap();
    }
    assert!(p.tenancy.accountant.usage_at("hog", p.clock.now_ms()) > 0.001, "hog over budget");
    // capped's second submission waits on its own max_concurrent.
    let c2 = p.run("capped", "mnist", quick(16, 2)).unwrap();
    assert_eq!(p.sessions.get(&c2).unwrap().node, None);
    for _ in 0..3 {
        p.drive_round(10).unwrap();
    }
    // The hog kept its session: preempting would have idled the GPU.
    let rec = p.sessions.get(&hog).unwrap();
    assert_eq!(rec.preemptions, 0, "no thrash for a quota-blocked waiter");
    assert_eq!(rec.state, SessionState::Running);
    // Everything still drains once capped's first session finishes.
    p.run_to_completion(10, 10_000).unwrap();
    for id in [&hog, &c1, &c2] {
        assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
    }
    assert_eq!(p.sessions.get(&hog).unwrap().preemptions, 0);
}

#[test]
fn mutually_over_budget_users_still_drain() {
    // Two users who both exhausted their budgets make each other
    // "contended"; the strict gate alone would wedge both lanes with
    // the GPU idle. The work-conserving fallback must drain them.
    let Some(p) = platform_with(1, 1) else { return };
    p.tenancy.registry.update_quota("alice", |q| q.gpu_second_budget = 0.001);
    p.tenancy.registry.update_quota("bob", |q| q.gpu_second_budget = 0.001);
    // Burn both budgets with one completed session each.
    let a1 = p.run("alice", "mnist", quick(16, 0)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let b1 = p.run("bob", "mnist", quick(16, 1)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let now = p.clock.now_ms();
    assert!(p.tenancy.accountant.usage_at("alice", now) > 0.001);
    assert!(p.tenancy.accountant.usage_at("bob", now) > 0.001);
    // A third user saturates the GPU; both over-budget users queue up.
    let c1 = p.run("carol", "mnist", quick(16, 2)).unwrap();
    let a2 = p.run("alice", "mnist", quick(16, 3)).unwrap();
    let b2 = p.run("bob", "mnist", quick(16, 4)).unwrap();
    assert_eq!(p.queued_total(), 2);
    // Once carol finishes the budget gate must not idle the GPU.
    p.run_to_completion(8, 10_000).unwrap();
    for id in [&a1, &b1, &c1, &a2, &b2] {
        assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
    }
}

#[test]
fn two_users_interleave_on_a_saturated_pool() {
    let Some(p) = platform_with(1, 1) else { return };
    let mut ids = Vec::new();
    for i in 0..4 {
        ids.push(p.run("alice", "mnist", quick(12, i)).unwrap());
    }
    for i in 0..4 {
        ids.push(p.run("bob", "mnist", quick(12, 10 + i)).unwrap());
    }
    p.run_to_completion(12, 10_000).unwrap();
    for id in &ids {
        assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
    }
    // Admission order interleaves the users instead of draining
    // alice's FIFO burst first: no run of 3+ same-user admissions, and
    // bob's first admission comes before alice's last.
    let admits: Vec<String> = p
        .events
        .bus()
        .read_since(0, 0, &EventFilter::default().with_kind("admission"))
        .events
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::AdmissionDecided { decision, user } if decision == "admit" => {
                Some(user.clone())
            }
            _ => None,
        })
        .collect();
    assert_eq!(admits.len(), 8, "{:?}", admits);
    let mut run = 1;
    for w in admits.windows(2) {
        run = if w[0] == w[1] { run + 1 } else { 1 };
        assert!(run <= 2, "fair share must interleave, got {:?}", admits);
    }
    let bob_first = admits.iter().position(|u| u == "bob").unwrap();
    let alice_last = admits.iter().rposition(|u| u == "alice").unwrap();
    assert!(bob_first < alice_last, "{:?}", admits);
}

#[test]
fn quota_verbs_round_trip_through_dispatch() {
    let Some(p) = platform_with(3, 4) else { return };
    let s = PlatformService::new(p);
    // set_quota acks and the report reflects it.
    let resp = s.dispatch(ApiRequest::SetQuota {
        user: "kim".into(),
        max_concurrent: Some(2),
        max_gpus: Some(4),
        gpu_second_budget: Some(9.5),
        weight: Some(3),
        class: Some("high".into()),
        max_qps: Some(50),
    });
    assert!(matches!(resp, ApiResponse::Ack { .. }), "{:?}", resp);
    let tenants = match s.dispatch(ApiRequest::TenantReport) {
        ApiResponse::Tenants { tenants } => tenants,
        other => panic!("{:?}", other),
    };
    let kim = tenants.iter().find(|t| t.user == "kim").expect("kim listed");
    assert_eq!(kim.max_concurrent, 2);
    assert_eq!(kim.max_gpus, 4);
    assert_eq!(kim.gpu_second_budget, 9.5);
    assert_eq!(kim.weight, 3);
    assert_eq!(kim.class, "high");

    // Partial update: only the named field changes.
    let resp = s.dispatch(ApiRequest::SetQuota {
        user: "kim".into(),
        max_concurrent: None,
        max_gpus: Some(8),
        gpu_second_budget: None,
        weight: None,
        class: None,
        max_qps: None,
    });
    assert!(matches!(resp, ApiResponse::Ack { .. }), "{:?}", resp);
    let q = s.platform().tenancy.registry.quota_of("kim");
    assert_eq!(q.max_gpus, 8);
    assert_eq!(q.max_concurrent, 2);

    // Unknown class and empty user are invalid_argument.
    for bad in [
        ApiRequest::SetQuota {
            user: "kim".into(),
            max_concurrent: None,
            max_gpus: None,
            gpu_second_budget: None,
            weight: None,
            class: Some("frobnicate".into()),
            max_qps: None,
        },
        ApiRequest::SetQuota {
            user: String::new(),
            max_concurrent: None,
            max_gpus: None,
            gpu_second_budget: None,
            weight: None,
            class: None,
            max_qps: None,
        },
    ] {
        match s.dispatch(bad) {
            ApiResponse::Error { error } => {
                assert_eq!(error.code, nsml::api::ErrorCode::InvalidArgument)
            }
            other => panic!("{:?}", other),
        }
    }

    // The mutation is audited; the query is not.
    let audit: Vec<String> = s
        .platform()
        .events
        .query(Some("api"), nsml::events::Level::Info)
        .iter()
        .map(|e| e.message())
        .collect();
    assert!(audit.iter().any(|m| m.contains("dispatch set_quota user=kim")), "{:?}", audit);
    assert!(!audit.iter().any(|m| m.contains("tenant_report")), "{:?}", audit);
}

#[test]
fn report_tracks_usage_and_queue_depth() {
    let Some(p) = platform_with(1, 1) else { return };
    let s = PlatformService::new(p);
    let resp = s.dispatch(ApiRequest::Run(nsml::api::RunParams::new("usr", "mnist")));
    assert!(!resp.is_error(), "{:?}", resp);
    // A second submission waits behind the saturated single GPU.
    let resp = s.dispatch(ApiRequest::Run(nsml::api::RunParams::new("usr", "mnist")));
    assert!(!resp.is_error(), "{:?}", resp);
    let tenants = match s.dispatch(ApiRequest::TenantReport) {
        ApiResponse::Tenants { tenants } => tenants,
        other => panic!("{:?}", other),
    };
    let usr = tenants.iter().find(|t| t.user == "usr").unwrap();
    assert_eq!(usr.active_sessions, 1);
    assert_eq!(usr.gpus_in_use, 1);
    assert_eq!(usr.waiting, 1);

    match s.dispatch(ApiRequest::RunToCompletion { chunk: 25, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("{:?}", other),
    }
    let tenants = match s.dispatch(ApiRequest::TenantReport) {
        ApiResponse::Tenants { tenants } => tenants,
        other => panic!("{:?}", other),
    };
    let usr = tenants.iter().find(|t| t.user == "usr").unwrap();
    assert_eq!(usr.active_sessions, 0);
    assert_eq!(usr.waiting, 0);
    assert!(usr.gpu_seconds_used > 0.0, "virtual GPU-seconds accounted");
}

#[test]
fn board_filters_by_user_with_global_ranks() {
    let Some(p) = platform_with(3, 4) else { return };
    let s = PlatformService::new(p);
    for (user, seed) in [("u1", 0u64), ("u2", 1), ("u1", 2)] {
        let mut params = nsml::api::RunParams::new(user, "mnist");
        params.total_steps = 16;
        params.eval_every = 8;
        params.checkpoint_every = 8;
        params.seed = seed;
        assert!(!s.dispatch(ApiRequest::Run(params)).is_error());
    }
    match s.dispatch(ApiRequest::RunToCompletion { chunk: 8, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("{:?}", other),
    }
    let all = match s.dispatch(ApiRequest::Board { dataset: "mnist".into(), limit: 10, user: None })
    {
        ApiResponse::Board { rows, .. } => rows,
        other => panic!("{:?}", other),
    };
    assert_eq!(all.len(), 3);
    let u1 = match s.dispatch(ApiRequest::Board {
        dataset: "mnist".into(),
        limit: 10,
        user: Some("u1".into()),
    }) {
        ApiResponse::Board { rows, .. } => rows,
        other => panic!("{:?}", other),
    };
    assert_eq!(u1.len(), 2);
    assert!(u1.iter().all(|r| r.user == "u1"), "{:?}", u1);
    // Filtered rows keep their global ranks.
    for row in &u1 {
        let global = all.iter().find(|r| r.session == row.session).unwrap();
        assert_eq!(row.rank, global.rank, "{:?}", row);
    }
    // An unknown user filters to an empty page, not an error.
    match s.dispatch(ApiRequest::Board {
        dataset: "mnist".into(),
        limit: 10,
        user: Some("nobody".into()),
    }) {
        ApiResponse::Board { rows, .. } => assert!(rows.is_empty()),
        other => panic!("{:?}", other),
    }
}

#[test]
fn disabled_tenancy_bypasses_admission() {
    let Some(art) = artifacts() else { return };
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = art;
    cfg.tenancy = false;
    let p = NsmlPlatform::new(cfg).unwrap();
    // Even a quota'd user goes straight to the scheduler.
    p.tenancy.registry.update_quota("free", |q| q.max_concurrent = 1);
    let a = p.run("free", "mnist", quick(12, 0)).unwrap();
    let b = p.run("free", "mnist", quick(12, 1)).unwrap();
    assert!(p.sessions.get(&a).unwrap().node.is_some());
    assert!(p.sessions.get(&b).unwrap().node.is_some(), "no admission gate when disabled");
    assert!(p.tenancy.admission.is_empty());
    p.run_to_completion(6, 10_000).unwrap();
}
