//! Wire-format guarantees: every `ApiRequest`/`ApiResponse` variant
//! round-trips through JSON, and a full session lifecycle
//! (run → pause → resume(new lr) → stop) can be driven purely through
//! `PlatformService::dispatch`.

use nsml::api::{
    ApiError, ApiRequest, ApiResponse, BoardRow, ClusterView, DurabilityView, EndpointVersionView,
    EndpointView, ErrorCode, ExecutorStats, NodeStatusView, NsmlPlatform, PlatformConfig,
    PlatformService, RunParams, ServiceStatusView, SessionView, TenantView, TrialSpec,
    WorkerStatView, ALL_KINDS, ALL_VERBS,
};
use nsml::session::SessionState;
use nsml::util::json::parse;
use std::collections::BTreeSet;
use std::path::PathBuf;

fn sample_requests() -> Vec<ApiRequest> {
    let mut run = RunParams::new("kim", "mnist");
    run.gpus = 2;
    run.total_steps = 120;
    run.lr = Some(0.05);
    run.seed = 3;
    run.use_scan = true;
    run.priority = "high".into();
    run.checkpoint_every = 30;
    run.eval_every = 15;
    vec![
        ApiRequest::Run(run),
        ApiRequest::Pause { session: "kim/mnist/1".into() },
        ApiRequest::Resume { session: "kim/mnist/1".into(), lr: Some(0.01) },
        ApiRequest::Resume { session: "kim/mnist/1".into(), lr: None },
        ApiRequest::Stop { session: "kim/mnist/1".into() },
        ApiRequest::Infer { session: "kim/mnist/1".into(), x: vec![0.0, 0.5, 1.0], shape: vec![1, 3] },
        ApiRequest::Drive { chunk: 25 },
        ApiRequest::RunToCompletion { chunk: 20, max_rounds: 10_000 },
        ApiRequest::KillNode { node: 2 },
        ApiRequest::list_sessions(),
        ApiRequest::ListSessions { limit: 5, offset: 10, user: Some("kim".into()) },
        ApiRequest::ServiceStatus,
        ApiRequest::GetSession { session: "kim/mnist/1".into() },
        ApiRequest::Board { dataset: "mnist".into(), limit: 10, user: None },
        ApiRequest::Board { dataset: "mnist".into(), limit: 10, user: Some("kim".into()) },
        ApiRequest::ClusterStatus,
        ApiRequest::ExecutorStatus,
        ApiRequest::TenantReport,
        ApiRequest::SetQuota {
            user: "kim".into(),
            max_concurrent: Some(2),
            max_gpus: Some(4),
            gpu_second_budget: Some(120.5),
            weight: Some(3),
            class: Some("high".into()),
            max_qps: Some(25),
        },
        ApiRequest::SetQuota {
            user: "lee".into(),
            max_concurrent: None,
            max_gpus: None,
            gpu_second_budget: None,
            weight: None,
            class: None,
            max_qps: None,
        },
        ApiRequest::DurabilityStatus,
        ApiRequest::EventsSince {
            since: 12,
            kind: Some("state".into()),
            subject: Some("kim/mnist/1".into()),
            limit: 50,
        },
        ApiRequest::EventsSince { since: 0, kind: None, subject: None, limit: 256 },
        ApiRequest::SubmitTrialBatch {
            user: "automl".into(),
            dataset: "mnist".into(),
            trials: vec![
                TrialSpec { lr: 0.1, seed: 0, total_steps: 40, gpus: 1 },
                TrialSpec { lr: 0.001, seed: 1, total_steps: 40, gpus: 2 },
            ],
        },
        ApiRequest::Promote {
            endpoint: "mnist-prod".into(),
            action: "promote".into(),
            session: Some("kim/mnist/1".into()),
        },
        ApiRequest::Promote {
            endpoint: "mnist-prod".into(),
            action: "rollback".into(),
            session: None,
        },
        ApiRequest::Endpoints,
        ApiRequest::ServeInfer {
            endpoint: "mnist-prod".into(),
            user: "kim".into(),
            x: vec![0.0, 0.5],
        },
    ]
}

fn sample_endpoint() -> EndpointView {
    EndpointView {
        name: "mnist-prod".into(),
        active_version: 2,
        model: "mnist_mlp".into(),
        session: "kim/mnist/2".into(),
        step: 120,
        replicas: 2,
        queue_depth: 5,
        p50_ms: 2.5,
        p99_ms: 12.0,
        versions: vec![
            EndpointVersionView {
                version: 1,
                session: "kim/mnist/1".into(),
                model: "mnist_mlp".into(),
                step: 100,
                promoted_at_ms: 5_000,
            },
            EndpointVersionView {
                version: 2,
                session: "kim/mnist/2".into(),
                model: "mnist_mlp".into(),
                step: 120,
                promoted_at_ms: 9_000,
            },
        ],
    }
}

fn sample_view() -> SessionView {
    SessionView {
        id: "kim/mnist/1".into(),
        user: "kim".into(),
        dataset: "mnist".into(),
        model: "mnist_mlp".into(),
        state: SessionState::Paused,
        node: Some(1),
        steps_done: 40,
        total_steps: 120,
        lr: 0.05,
        best_metric: Some(0.91),
        recoveries: 1,
        preemptions: 2,
    }
}

fn sample_responses() -> Vec<ApiResponse> {
    vec![
        ApiResponse::Submitted { session: "kim/mnist/1".into() },
        ApiResponse::BatchSubmitted { sessions: vec!["a/mnist/1".into(), "a/mnist/2".into()] },
        ApiResponse::Ack { verb: "pause".into(), session: Some("kim/mnist/1".into()) },
        ApiResponse::Ack { verb: "run_to_completion".into(), session: None },
        ApiResponse::Progressed { sessions: 3 },
        ApiResponse::Probs { probs: vec![0.125, 0.5, 0.375] },
        ApiResponse::Sessions { sessions: vec![sample_view()] },
        ApiResponse::Session {
            session: SessionView { state: SessionState::Done, node: None, best_metric: None, ..sample_view() }
        },
        ApiResponse::Board {
            dataset: "mnist".into(),
            rows: vec![BoardRow {
                rank: 1,
                session: "kim/mnist/1".into(),
                user: "kim".into(),
                model: "mnist_mlp".into(),
                metric: "accuracy".into(),
                value: 0.91,
                step: 120,
            }],
        },
        ApiResponse::Cluster {
            cluster: ClusterView {
                nodes: vec![NodeStatusView {
                    hostname: "node-0".into(),
                    alive: true,
                    total_gpus: 4,
                    free_gpus: 2,
                    jobs: vec!["kim/mnist/1".into()],
                }],
                total_gpus: 4,
                free_gpus: 2,
                utilization: 0.5,
                queue_len: 1,
                policy: "best_fit".into(),
                fast_path: true,
                leader: Some("sched-0".into()),
                epoch: 2,
            },
        },
        ApiResponse::Executor {
            executor: ExecutorStats {
                workers: vec![
                    WorkerStatView {
                        worker: 0,
                        live_sessions: 3,
                        queue_depth: 1,
                        steals: 0,
                        busy_ms: 42.5,
                    },
                    WorkerStatView {
                        worker: 1,
                        live_sessions: 2,
                        queue_depth: 0,
                        steals: 2,
                        busy_ms: 39.0,
                    },
                ],
                live_sessions: 5,
                queue_depth: 1,
                total_steals: 2,
                work_steal: true,
            },
        },
        ApiResponse::Events {
            events: vec![
                nsml::events::Event {
                    seq: 41,
                    at_ms: 900,
                    level: nsml::events::Level::Info,
                    source: "scheduler".into(),
                    subject: "kim/mnist/1".into(),
                    kind: nsml::events::EventKind::PlacementDecided { node: 2, from_queue: true },
                },
                nsml::events::Event {
                    seq: 42,
                    at_ms: 1000,
                    level: nsml::events::Level::Info,
                    source: "session".into(),
                    subject: "kim/mnist/1".into(),
                    kind: nsml::events::EventKind::StateChanged {
                        from: "running".into(),
                        to: "done".into(),
                        step: 120,
                    },
                },
            ],
            next: 43,
            dropped: 7,
            overflow: 12,
        },
        ApiResponse::Tenants {
            tenants: vec![
                TenantView {
                    user: "kim".into(),
                    weight: 3,
                    class: "high".into(),
                    max_concurrent: 2,
                    max_gpus: 4,
                    gpu_second_budget: 120.5,
                    gpu_seconds_used: 17.25,
                    active_sessions: 1,
                    gpus_in_use: 2,
                    waiting: 1,
                    preemptions: 1,
                },
                TenantView {
                    user: "lee".into(),
                    weight: 1,
                    class: "normal".into(),
                    max_concurrent: 0,
                    max_gpus: 0,
                    gpu_second_budget: 0.0,
                    gpu_seconds_used: 0.0,
                    active_sessions: 0,
                    gpus_in_use: 0,
                    waiting: 0,
                    preemptions: 0,
                },
            ],
        },
        ApiResponse::Durability {
            durability: DurabilityView {
                enabled: true,
                wal_records: 12,
                wal_bytes: 2048,
                wal_last_seq: Some(99),
                records_since_snapshot: 12,
                snapshot_every: 512,
                snapshots: 3,
                last_snapshot_seq: 87,
                wal_dropped: 0,
                consumer_dropped: 1,
                gc_enabled: true,
                gc_live_objects: 40,
                gc_live_bytes: 1 << 20,
                gc_swept_objects: 7,
                gc_swept_bytes: 4096,
            },
        },
        ApiResponse::Service {
            service: ServiceStatusView {
                running: true,
                rounds: 420,
                last_round_ms: 3.5,
                rounds_per_sec: 150.25,
                progressed_total: 980,
                dispatches: 17,
            },
        },
        ApiResponse::Endpoint { endpoint: sample_endpoint() },
        ApiResponse::Endpoints { endpoints: vec![sample_endpoint()] },
        ApiResponse::Endpoints { endpoints: vec![] },
        ApiResponse::Served {
            endpoint: "mnist-prod".into(),
            version: 2,
            batch: 8,
            probs: vec![0.25, 0.75],
        },
        ApiResponse::Error {
            error: ApiError::failed("session kim/mnist/1 is not active").with_session("kim/mnist/1"),
        },
    ]
}

#[test]
fn every_request_variant_round_trips() {
    let samples = sample_requests();
    let verbs: BTreeSet<&str> = samples.iter().map(|r| r.verb()).collect();
    assert_eq!(
        verbs,
        ALL_VERBS.iter().copied().collect::<BTreeSet<&str>>(),
        "sample set must cover every verb"
    );
    for req in samples {
        let text = req.to_json().to_string();
        let back = ApiRequest::from_json(&parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{} failed to parse back: {} ({})", req.verb(), e, text));
        assert_eq!(back, req, "wire round-trip for {}:\n{}", req.verb(), text);
    }
}

#[test]
fn every_response_variant_round_trips() {
    let samples = sample_responses();
    let kinds: BTreeSet<&str> = samples.iter().map(|r| r.kind()).collect();
    assert_eq!(
        kinds,
        ALL_KINDS.iter().copied().collect::<BTreeSet<&str>>(),
        "sample set must cover every kind"
    );
    for resp in samples {
        let text = resp.to_json().to_string();
        let back = ApiResponse::from_json(&parse(&text).unwrap())
            .unwrap_or_else(|e| panic!("{} failed to parse back: {} ({})", resp.kind(), e, text));
        assert_eq!(back, resp, "wire round-trip for {}:\n{}", resp.kind(), text);
    }
}

#[test]
fn request_verbs_match_post_route_names() {
    // `POST /api/v1/<verb>` builds requests from (verb, args); every verb
    // must therefore reconstruct from its own envelope's parts.
    for req in sample_requests() {
        let env = req.to_json();
        let args = env.get("args").unwrap();
        let back = ApiRequest::from_verb_args(req.verb(), args).unwrap();
        assert_eq!(back, req);
    }
}

// ---------------------------------------------------------------------
// End-to-end lifecycle purely through dispatch
// ---------------------------------------------------------------------

fn service() -> Option<PlatformService> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = dir;
    Some(PlatformService::new(NsmlPlatform::new(cfg).unwrap()))
}

fn get_view(s: &PlatformService, id: &str) -> SessionView {
    match s.dispatch(ApiRequest::GetSession { session: id.to_string() }) {
        ApiResponse::Session { session } => session,
        other => panic!("get_session: {:?}", other),
    }
}

#[test]
fn dispatch_drives_run_pause_resume_stop() {
    let Some(s) = service() else { return };

    // run
    let mut params = RunParams::new("wire", "mnist");
    params.total_steps = 120;
    params.checkpoint_every = 30;
    params.eval_every = 30;
    let id = match s.dispatch(ApiRequest::Run(params)) {
        ApiResponse::Submitted { session } => session,
        other => panic!("run: {:?}", other),
    };

    // drive until mid-training
    while get_view(&s, &id).steps_done < 30 {
        match s.dispatch(ApiRequest::Drive { chunk: 10 }) {
            ApiResponse::Progressed { .. } => {}
            other => panic!("drive: {:?}", other),
        }
    }

    // pause
    match s.dispatch(ApiRequest::Pause { session: id.clone() }) {
        ApiResponse::Ack { verb, session } => {
            assert_eq!(verb, "pause");
            assert_eq!(session.as_deref(), Some(id.as_str()));
        }
        other => panic!("pause: {:?}", other),
    }
    assert_eq!(get_view(&s, &id).state, SessionState::Paused);
    // A paused session does not advance.
    let frozen = get_view(&s, &id).steps_done;
    s.dispatch(ApiRequest::Drive { chunk: 10 });
    assert_eq!(get_view(&s, &id).steps_done, frozen);

    // resume with a new lr (the §3.3 in-training edit)
    match s.dispatch(ApiRequest::Resume { session: id.clone(), lr: Some(0.05) }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("resume: {:?}", other),
    }
    assert_eq!(get_view(&s, &id).state, SessionState::Running);

    // finish
    match s.dispatch(ApiRequest::RunToCompletion { chunk: 20, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("run_to_completion: {:?}", other),
    }
    let done = get_view(&s, &id);
    assert_eq!(done.state, SessionState::Done);
    assert_eq!(done.steps_done, 120);

    // infer against the finished session, over the wire
    let x: Vec<f32> = vec![0.5; 64 * 144];
    match s.dispatch(ApiRequest::Infer { session: id.clone(), x, shape: vec![64, 144] }) {
        ApiResponse::Probs { probs } => assert_eq!(probs.len(), 640),
        other => panic!("infer: {:?}", other),
    }

    // the board lists it
    match s.dispatch(ApiRequest::Board { dataset: "mnist".into(), limit: 10, user: None }) {
        ApiResponse::Board { rows, .. } => {
            assert!(rows.iter().any(|r| r.session == id), "{:?}", rows);
        }
        other => panic!("board: {:?}", other),
    }

    // stop a terminal session still acks (idempotent cleanup path)
    match s.dispatch(ApiRequest::Stop { session: id.clone() }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("stop: {:?}", other),
    }

    // the audit trail recorded every mutation verb
    let audit: Vec<String> = s
        .platform()
        .events
        .query(Some("api"), nsml::events::Level::Info)
        .iter()
        .map(|e| e.message())
        .collect();
    for verb in ["dispatch run", "dispatch pause", "dispatch resume", "dispatch stop"] {
        assert!(audit.iter().any(|m| m.starts_with(verb)), "missing '{}' in {:?}", verb, audit);
    }
}

#[test]
fn trial_batch_places_and_completes_all() {
    let Some(s) = service() else { return };
    let trials: Vec<TrialSpec> = [0.001, 0.1, 1.0]
        .iter()
        .map(|&lr| TrialSpec { lr, seed: 2, total_steps: 16, gpus: 1 })
        .collect();
    let sessions = match s.dispatch(ApiRequest::SubmitTrialBatch {
        user: "batch".into(),
        dataset: "mnist".into(),
        trials,
    }) {
        ApiResponse::BatchSubmitted { sessions } => sessions,
        other => panic!("batch: {:?}", other),
    };
    assert_eq!(sessions.len(), 3);
    match s.dispatch(ApiRequest::RunToCompletion { chunk: 8, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("run_to_completion: {:?}", other),
    }
    for id in &sessions {
        assert_eq!(get_view(&s, id).state, SessionState::Done, "{}", id);
    }
    // A failing batch reports which trial broke and places nothing new.
    let before = match s.dispatch(ApiRequest::list_sessions()) {
        ApiResponse::Sessions { sessions } => sessions.len(),
        other => panic!("{:?}", other),
    };
    let resp = s.dispatch(ApiRequest::SubmitTrialBatch {
        user: "batch".into(),
        dataset: "no-such-dataset".into(),
        trials: vec![TrialSpec { lr: 0.1, seed: 0, total_steps: 8, gpus: 1 }],
    });
    match resp {
        ApiResponse::Error { error } => assert!(error.message.contains("trial 0"), "{}", error),
        other => panic!("{:?}", other),
    }
    match s.dispatch(ApiRequest::list_sessions()) {
        ApiResponse::Sessions { sessions } => assert_eq!(sessions.len(), before),
        other => panic!("{:?}", other),
    }
}

// ---------------------------------------------------------------------
// Infer request validation (shape vs data vs compiled model input)
// ---------------------------------------------------------------------

#[test]
fn infer_rejects_mismatched_shapes_before_the_engine() {
    let Some(s) = service() else { return };
    let mut params = RunParams::new("shape", "mnist");
    params.total_steps = 8;
    params.checkpoint_every = 4;
    params.eval_every = 4;
    let id = match s.dispatch(ApiRequest::Run(params)) {
        ApiResponse::Submitted { session } => session,
        other => panic!("run: {:?}", other),
    };
    match s.dispatch(ApiRequest::RunToCompletion { chunk: 8, max_rounds: 10_000 }) {
        ApiResponse::Ack { .. } => {}
        other => panic!("run_to_completion: {:?}", other),
    }

    // Shape product disagreeing with the flat data length: the error
    // names both sizes so the client can see what to fix.
    let resp = s.dispatch(ApiRequest::Infer {
        session: id.clone(),
        x: vec![0.0; 100],
        shape: vec![64, 144],
    });
    match resp {
        ApiResponse::Error { error } => {
            assert_eq!(error.code, ErrorCode::InvalidArgument);
            assert!(
                error.message.contains("9216") && error.message.contains("100"),
                "must name both sizes: {}",
                error.message
            );
        }
        other => panic!("count mismatch: {:?}", other),
    }

    // A self-consistent request whose shape is not the compiled
    // model's input must be a client error too, never an engine crash.
    let resp = s.dispatch(ApiRequest::Infer {
        session: id.clone(),
        x: vec![0.0; 32 * 144],
        shape: vec![32, 144],
    });
    match resp {
        ApiResponse::Error { error } => {
            assert_eq!(error.code, ErrorCode::InvalidArgument);
            assert!(
                error.message.contains("[32, 144]") && error.message.contains("[64, 144]"),
                "must name both shapes: {}",
                error.message
            );
        }
        other => panic!("shape mismatch: {:?}", other),
    }

    // Degenerate shapes (empty, zero or negative dims) are invalid
    // regardless of the data length.
    for shape in [vec![], vec![0, 144], vec![-64, -144]] {
        let resp = s.dispatch(ApiRequest::Infer { session: id.clone(), x: vec![0.0; 4], shape });
        match resp {
            ApiResponse::Error { error } => assert_eq!(error.code, ErrorCode::InvalidArgument),
            other => panic!("degenerate shape: {:?}", other),
        }
    }

    // The correctly-shaped request still works after all that.
    let resp = s.dispatch(ApiRequest::Infer {
        session: id,
        x: vec![0.5; 64 * 144],
        shape: vec![64, 144],
    });
    match resp {
        ApiResponse::Probs { probs } => assert_eq!(probs.len(), 640),
        other => panic!("valid infer: {:?}", other),
    }
}
