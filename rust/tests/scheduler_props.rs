//! Property-based tests on scheduler/cluster invariants, driven by the
//! in-repo quickcheck harness over random operation sequences.

use nsml::cluster::{Cluster, NodeId, ResourceReq};
use nsml::events::EventLog;
use nsml::scheduler::{policy_by_name, JobSpec, Master, Priority};
use nsml::util::clock::sim_clock;
use nsml::util::quickcheck::{ensure, forall};
use nsml::util::rng::Rng;

fn mk_master(nodes: usize, gpus: usize, policy: &str) -> Master {
    let (clock, _) = sim_clock();
    let events = EventLog::new(clock.clone()).with_echo(false);
    let cluster = Cluster::homogeneous(clock, events.clone(), nodes, gpus, 24.0);
    Master::new(cluster, policy_by_name(policy, 7), events)
}

/// Op stream: 0..=59 submit, 60..=79 complete-oldest, 80..=89 kill node,
/// 90..=99 revive node.
fn run_ops(master: &Master, ops: &[u64]) {
    let mut submitted = 0u64;
    let mut live: Vec<String> = Vec::new();
    for &op in ops {
        match op % 100 {
            0..=59 => {
                let id = format!("j{}", submitted);
                submitted += 1;
                let gpus = 1 + (op / 100 % 4) as usize;
                let pri = match op % 3 {
                    0 => Priority::Low,
                    1 => Priority::Normal,
                    _ => Priority::High,
                };
                master.submit(JobSpec::new(&id, gpus).with_priority(pri));
                live.push(id);
            }
            60..=79 => {
                if let Some(id) = live.first().cloned() {
                    live.remove(0);
                    master.complete(&id);
                }
            }
            80..=89 => {
                let node = NodeId((op % 3) as u32);
                let orphans = master.cluster().kill_node(node);
                master.handle_orphans(&orphans);
            }
            _ => {
                master.cluster().revive_node(NodeId((op % 3) as u32));
                master.pump();
            }
        }
    }
}

#[test]
fn no_gpu_oversubscription_under_random_ops() {
    forall(
        11,
        60,
        |rng: &mut Rng| (0..120).map(|_| rng.below(1000)).collect::<Vec<u64>>(),
        |ops| {
            let master = mk_master(3, 4, "best_fit");
            run_ops(&master, ops);
            for view in master.cluster().snapshot() {
                ensure(view.free_gpus <= view.total_gpus, "free exceeds total")?;
                // Each running job's GPUs are within its node's capacity.
            }
            let (total, free) = master.cluster().gpu_totals();
            ensure(free <= total, "free > total")?;
            Ok(())
        },
    );
}

#[test]
fn job_conservation_under_random_ops() {
    forall(
        12,
        60,
        |rng: &mut Rng| (0..150).map(|_| rng.below(1000)).collect::<Vec<u64>>(),
        |ops| {
            let master = mk_master(3, 4, "first_fit");
            run_ops(&master, ops);
            let s = master.stats();
            let accounted =
                master.running_jobs().len() as u64 + master.queue_len() as u64 + s.completed + s.cancelled;
            ensure(
                accounted == s.submitted,
                &format!("conservation violated: {} accounted vs {} submitted ({:?})", accounted, s.submitted, s),
            )
        },
    );
}

#[test]
fn placements_always_fit_for_every_policy() {
    for policy in ["best_fit", "first_fit", "worst_fit", "random"] {
        forall(
            13,
            30,
            |rng: &mut Rng| (0..100).map(|_| rng.below(1000)).collect::<Vec<u64>>(),
            |ops| {
                let master = mk_master(4, 8, policy);
                run_ops(&master, ops);
                // Every running job is on an alive node.
                for (job, node) in master.running_jobs() {
                    let snap = master.cluster().snapshot();
                    let view = snap.iter().find(|v| v.id == node);
                    ensure(view.is_some(), &format!("job {} on unknown node", job.id))?;
                    ensure(
                        view.unwrap().jobs.contains(&job.id),
                        &format!("node does not list job {}", job.id),
                    )?;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn queue_drains_when_cluster_empties() {
    forall(
        14,
        40,
        |rng: &mut Rng| (0..40).map(|_| 1 + rng.below(4)).collect::<Vec<u64>>(),
        |gpu_sizes| {
            let master = mk_master(2, 4, "best_fit");
            // Submit everything; then complete running jobs until both
            // queue and cluster are empty. Work-conservation: as long as
            // the queue is non-empty, completing jobs must eventually
            // place more.
            for (i, g) in gpu_sizes.iter().enumerate() {
                master.submit(JobSpec::new(&format!("j{}", i), *g as usize));
            }
            let mut guard = 0;
            while master.queue_len() > 0 || !master.running_jobs().is_empty() {
                guard += 1;
                ensure(guard < 10_000, "scheduler wedged")?;
                let running = master.running_jobs();
                if let Some((job, _)) = running.first() {
                    master.complete(&job.id);
                } else if master.queue_len() > 0 {
                    let placed = master.pump();
                    ensure(!placed.is_empty(), "queue non-empty, cluster idle, nothing placed")?;
                }
            }
            let s = master.stats();
            ensure(s.completed == gpu_sizes.len() as u64, "not all jobs completed")
        },
    );
}

#[test]
fn election_has_at_most_one_leader_under_chaos() {
    use nsml::scheduler::ElectionGroup;
    forall(
        15,
        40,
        |rng: &mut Rng| (0..60).map(|_| rng.below(100)).collect::<Vec<u64>>(),
        |ops| {
            let (clock, sim) = sim_clock();
            let events = EventLog::new(clock.clone()).with_echo(false);
            let group = ElectionGroup::new(clock, events, 4);
            let mut epochs_seen = vec![group.epoch()];
            for &op in ops {
                match op % 10 {
                    0..=2 => group.kill(nsml::scheduler::ReplicaId((op % 4) as u32)),
                    3..=5 => group.revive(nsml::scheduler::ReplicaId((op % 4) as u32)),
                    _ => {
                        for r in group.replica_ids() {
                            group.heartbeat(r);
                        }
                    }
                }
                sim.advance(op % 50);
                group.tick();
                // Leader, if any, must be an alive replica; epochs never regress.
                let epoch = group.epoch();
                ensure(epoch >= *epochs_seen.last().unwrap(), "epoch regressed")?;
                epochs_seen.push(epoch);
            }
            Ok(())
        },
    );
}
