//! Gating suite for the inference-serving subsystem: micro-batched
//! execution is bitwise-identical to serving each request alone, the
//! endpoint lifecycle (promote → rollback → rollforward → retire)
//! holds end to end through dispatch, a rollback drains the replica
//! set so no batch mixes endpoint versions, the autoscaler grows and
//! shrinks the set through the drive loop, concurrent daemon clients
//! are all answered with their own results, QPS quotas reject with
//! machine-readable envelopes (and the sliding window is exact at
//! window boundaries), and the batcher's flush policy obeys its
//! invariants under arbitrary arrival patterns.

use nsml::api::{
    ApiRequest, ApiResponse, DaemonOpts, ErrorCode, NsmlPlatform, PlatformConfig, PlatformService,
    RunOpts,
};
use nsml::serving::{PendingInfer, ServingQueue};
use nsml::tenancy::TenantQuota;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One `mnist_mlp` request row (`infer_x_shape[1..]` = 144 values).
const ROW: usize = 144;

fn platform() -> Option<NsmlPlatform> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = dir;
    Some(NsmlPlatform::new(cfg).unwrap())
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 2).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

/// Train one quick session and wrap the platform in a service.
fn trained_service(user: &str) -> Option<(PlatformService, String)> {
    let p = platform()?;
    let id = p.run(user, "mnist", quick(16, 0)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    Some((PlatformService::new(p), id))
}

/// A deterministic, per-seed-distinct input row.
fn row(seed: usize) -> Vec<f32> {
    (0..ROW).map(|i| ((seed * 31 + i * 7) % 97) as f32 / 97.0).collect()
}

fn promote(s: &PlatformService, endpoint: &str, session: &str) -> u64 {
    match s.dispatch(ApiRequest::Promote {
        endpoint: endpoint.into(),
        action: "promote".into(),
        session: Some(session.into()),
    }) {
        ApiResponse::Endpoint { endpoint } => endpoint.active_version,
        other => panic!("promote: {:?}", other),
    }
}

fn serve_one(s: &PlatformService, endpoint: &str, user: &str, x: Vec<f32>) -> (u64, u64, Vec<f32>) {
    match s.dispatch(ApiRequest::ServeInfer { endpoint: endpoint.into(), user: user.into(), x }) {
        ApiResponse::Served { version, batch, probs, .. } => (version, batch, probs),
        other => panic!("serve_infer: {:?}", other),
    }
}

/// Replies from the executor serve lane fire asynchronously from
/// worker threads; spin (briefly) until `done` or fail the test.
fn wait_until(what: &str, mut done: impl FnMut() -> bool) {
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while !done() {
        assert!(std::time::Instant::now() < deadline, "timed out waiting for {}", what);
        std::thread::sleep(Duration::from_millis(2));
    }
}

// ---------------------------------------------------------------------
// Batched == sequential, bit for bit
// ---------------------------------------------------------------------

#[test]
fn batched_serving_is_bitwise_identical_to_sequential() {
    let Some((s, id)) = trained_service("serve") else { return };
    promote(&s, "prod", &id);

    let rows: Vec<Vec<f32>> = (0..48).map(row).collect();

    // Sequential: one dispatch per request, each executing alone.
    let mut sequential = Vec::new();
    for r in &rows {
        let (version, batch, probs) = serve_one(&s, "prod", "kim", r.clone());
        assert_eq!(version, 1);
        assert_eq!(batch, 1, "a lone request serves in a batch of one");
        assert_eq!(probs.len(), 10, "one output row per request");
        sequential.push(probs);
    }

    // Batched: queue all 48 on the facade, flush once — a single
    // fixed-shape engine execution answers everyone.
    let results: Arc<Mutex<Vec<Option<(Vec<f32>, usize)>>>> =
        Arc::new(Mutex::new(vec![None; rows.len()]));
    let p = s.platform();
    for (i, r) in rows.iter().enumerate() {
        let slot = results.clone();
        p.serve_enqueue(
            "prod",
            "kim",
            r.clone(),
            Box::new(move |res| {
                let row = res.expect("batched serve failed");
                slot.lock().unwrap()[i] = Some((row.probs, row.batch));
            }),
        )
        .unwrap();
    }
    assert_eq!(p.serving_stats().depth, rows.len());
    p.pump_serving(true);
    assert_eq!(p.serving_stats().depth, 0, "flush dispatches everything");
    // The batch executes on a replica's worker thread; replies land
    // asynchronously.
    wait_until("the shared batch to answer", || {
        results.lock().unwrap().iter().all(Option::is_some)
    });

    let batched = results.lock().unwrap();
    for (i, probs) in sequential.iter().enumerate() {
        let (b, size) = batched[i].as_ref().expect("request answered");
        assert_eq!(*size, rows.len(), "all 48 shared one batch");
        assert_eq!(b, probs, "row {}: batched output must be bitwise identical", i);
    }

    // The latency/batch telemetry event fired for the shared batch —
    // the worker publishes it right after the replies, so poll.
    wait_until("the 48-row InferServed telemetry event", || {
        let batch_events = p.events.bus().read_since(
            0,
            0,
            &nsml::events::EventFilter { kind: Some("infer".into()), ..Default::default() },
        );
        batch_events.events.iter().any(|e| match &e.kind {
            nsml::events::EventKind::InferServed { batch, .. } => *batch == rows.len() as u64,
            _ => false,
        })
    });
}

// ---------------------------------------------------------------------
// Endpoint lifecycle through dispatch
// ---------------------------------------------------------------------

#[test]
fn promote_roll_lifecycle_and_errors() {
    let Some(p) = platform() else { return };
    let s1 = p.run("kim", "mnist", quick(16, 1)).unwrap();
    let s2 = p.run("kim", "mnist", quick(16, 2)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let s = PlatformService::new(p);

    assert_eq!(promote(&s, "prod", &s1), 1);
    assert_eq!(promote(&s, "prod", &s2), 2);

    // The registry lists one endpoint: active v2, full history kept.
    match s.dispatch(ApiRequest::Endpoints) {
        ApiResponse::Endpoints { endpoints } => {
            assert_eq!(endpoints.len(), 1);
            assert_eq!(endpoints[0].name, "prod");
            assert_eq!(endpoints[0].active_version, 2);
            assert_eq!(endpoints[0].session, s2);
            assert_eq!(endpoints[0].versions.len(), 2);
        }
        other => panic!("endpoints: {:?}", other),
    }

    // Serving attributes the active version.
    let (v, _, probs_v2) = serve_one(&s, "prod", "kim", row(0));
    assert_eq!(v, 2);

    // Rollback: v1 becomes active and serving follows the cursor.
    let rolled = match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "rollback".into(),
        session: None,
    }) {
        ApiResponse::Endpoint { endpoint } => endpoint,
        other => panic!("rollback: {:?}", other),
    };
    assert_eq!(rolled.active_version, 1);
    assert_eq!(rolled.session, s1);
    let (v, _, _) = serve_one(&s, "prod", "kim", row(0));
    assert_eq!(v, 1);

    // Rolling past the oldest version is a precondition failure.
    match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "rollback".into(),
        session: None,
    }) {
        ApiResponse::Error { error } => {
            assert_eq!(error.code, ErrorCode::FailedPrecondition, "{}", error.message)
        }
        other => panic!("rollback past oldest: {:?}", other),
    }

    // Rollforward returns to v2 — and v2 serves the same bits as
    // before the roll trip.
    match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "rollforward".into(),
        session: None,
    }) {
        ApiResponse::Endpoint { endpoint } => assert_eq!(endpoint.active_version, 2),
        other => panic!("rollforward: {:?}", other),
    }
    let (v, _, probs_again) = serve_one(&s, "prod", "kim", row(0));
    assert_eq!(v, 2);
    assert_eq!(probs_again, probs_v2, "same version must serve the same output");

    // Unknown endpoint → 404-class errors for both control and data paths.
    match s.dispatch(ApiRequest::Promote {
        endpoint: "nope".into(),
        action: "rollback".into(),
        session: None,
    }) {
        ApiResponse::Error { error } => assert_eq!(error.code, ErrorCode::NotFound),
        other => panic!("{:?}", other),
    }
    match s.dispatch(ApiRequest::ServeInfer {
        endpoint: "nope".into(),
        user: "kim".into(),
        x: row(0),
    }) {
        ApiResponse::Error { error } => assert_eq!(error.code, ErrorCode::NotFound),
        other => panic!("{:?}", other),
    }

    // Wrong-length input is rejected before the engine, naming both sizes.
    match s.dispatch(ApiRequest::ServeInfer {
        endpoint: "prod".into(),
        user: "kim".into(),
        x: vec![0.0; 3],
    }) {
        ApiResponse::Error { error } => {
            assert_eq!(error.code, ErrorCode::InvalidArgument);
            assert!(
                error.message.contains('3') && error.message.contains("144"),
                "must name both sizes: {}",
                error.message
            );
        }
        other => panic!("{:?}", other),
    }

    // Promoting a session that has no checkpoints is a precondition
    // failure, not a served endpoint.
    let fresh = s.platform().run("kim", "mnist", quick(16, 3)).unwrap();
    match s.dispatch(ApiRequest::Promote {
        endpoint: "early".into(),
        action: "promote".into(),
        session: Some(fresh.clone()),
    }) {
        ApiResponse::Error { error } => {
            assert_eq!(error.code, ErrorCode::FailedPrecondition, "{}", error.message)
        }
        other => panic!("promote without checkpoint: {:?}", other),
    }

    // Retire: the endpoint disappears and serving 404s afterward.
    match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "retire".into(),
        session: None,
    }) {
        ApiResponse::Ack { verb, .. } => assert_eq!(verb, "retire"),
        other => panic!("retire: {:?}", other),
    }
    match s.dispatch(ApiRequest::Endpoints) {
        ApiResponse::Endpoints { endpoints } => assert!(endpoints.is_empty()),
        other => panic!("{:?}", other),
    }
    match s.dispatch(ApiRequest::ServeInfer {
        endpoint: "prod".into(),
        user: "kim".into(),
        x: row(0),
    }) {
        ApiResponse::Error { error } => assert_eq!(error.code, ErrorCode::NotFound),
        other => panic!("serve after retire: {:?}", other),
    }
}

// ---------------------------------------------------------------------
// Concurrent clients through the daemon
// ---------------------------------------------------------------------

#[test]
fn concurrent_daemon_clients_all_get_their_own_answer() {
    let Some((s, id)) = trained_service("conc") else { return };
    promote(&s, "prod", &id);

    // Expected outputs computed on the sync path before the daemon
    // starts (the endpoint's checkpoint is immutable, so training more
    // sessions later cannot change them).
    const CLIENTS: usize = 12;
    let expected: Vec<Vec<f32>> =
        (0..CLIENTS).map(|i| serve_one(&s, "prod", "kim", row(i)).2).collect();

    // N client threads dispatch concurrently; the daemon runs on this
    // thread (the platform owner) and exits when every handle drops.
    let (handle, rx) = nsml::api::service_channel();
    let clients: Vec<_> = (0..CLIENTS)
        .map(|i| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let resp = h.call(ApiRequest::ServeInfer {
                    endpoint: "prod".into(),
                    user: format!("user{}", i % 3),
                    x: row(i),
                });
                (i, resp)
            })
        })
        .collect();
    // One more client keeps the daemon's *active* branch exercised:
    // training runs in the background while requests serve.
    let trainer = {
        let h = handle.clone();
        std::thread::spawn(move || {
            let mut params = nsml::api::RunParams::new("bg", "mnist");
            params.total_steps = 40;
            params.checkpoint_every = 20;
            params.eval_every = 10;
            h.call(ApiRequest::Run(params))
        })
    };
    drop(handle);
    let opts = DaemonOpts { idle_wait: Duration::from_millis(2), ..DaemonOpts::default() };
    s.run_daemon(&rx, &opts).unwrap();

    match trainer.join().unwrap() {
        ApiResponse::Submitted { .. } => {}
        other => panic!("background run: {:?}", other),
    }
    let mut answered = 0;
    for c in clients {
        let (i, resp) = c.join().unwrap();
        match resp {
            ApiResponse::Served { endpoint, version, batch, probs } => {
                assert_eq!(endpoint, "prod");
                assert_eq!(version, 1);
                assert!(batch >= 1, "batch attribution present");
                assert_eq!(probs, expected[i], "client {} got someone else's answer", i);
                answered += 1;
            }
            other => panic!("client {}: {:?}", i, other),
        }
    }
    assert_eq!(answered, CLIENTS, "every client answered exactly once");
    // Nothing left pending; the queue counted every request.
    let stats = s.platform().serving_stats();
    assert_eq!(stats.depth, 0);
    assert_eq!(stats.requests, (CLIENTS * 2) as u64);
}

// ---------------------------------------------------------------------
// Replica drain: no mixed-version batches across a rollback
// ---------------------------------------------------------------------

#[test]
fn rollback_drains_in_flight_replicas_without_mixing_versions() {
    let Some(p) = platform() else { return };
    let s1 = p.run("kim", "mnist", quick(16, 4)).unwrap();
    let s2 = p.run("kim", "mnist", quick(16, 5)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let s = PlatformService::new(p);
    assert_eq!(promote(&s, "prod", &s1), 1);
    assert_eq!(promote(&s, "prod", &s2), 2);

    // Queue a burst at v2 but do NOT pump: the requests are still
    // sitting in the micro-batcher when the rollback arrives.
    const K: usize = 24;
    let versions: Arc<Mutex<Vec<Option<u64>>>> = Arc::new(Mutex::new(vec![None; K]));
    let p = s.platform();
    for i in 0..K {
        let slot = versions.clone();
        p.serve_enqueue(
            "prod",
            "kim",
            row(i),
            Box::new(move |res| {
                let served = res.expect("a queued request must serve, not fail");
                slot.lock().unwrap()[i] = Some(served.version);
            }),
        )
        .unwrap();
    }
    assert_eq!(p.serving_stats().depth, K);

    // Rollback quiesces first: the queue flushes at v2 and the replica
    // set drains before the active cursor moves, so by the time the
    // rollback *returns*, every queued request has answered — at v2.
    match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "rollback".into(),
        session: None,
    }) {
        ApiResponse::Endpoint { endpoint } => assert_eq!(endpoint.active_version, 1),
        other => panic!("rollback: {:?}", other),
    }
    let answered: Vec<u64> = versions
        .lock()
        .unwrap()
        .iter()
        .map(|v| v.expect("drain completed before the rollback returned"))
        .collect();
    assert!(
        answered.iter().all(|&v| v == 2),
        "no batch mixes endpoint versions across the rollback: {:?}",
        answered
    );

    // The next request serves the rolled-back version.
    let (v, _, _) = serve_one(&s, "prod", "kim", row(0));
    assert_eq!(v, 1);
}

// ---------------------------------------------------------------------
// Autoscaling through the drive loop
// ---------------------------------------------------------------------

#[test]
fn autoscaler_grows_on_backlog_and_shrinks_after_idle() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = dir;
    cfg.serving_scale_up_queue_depth = 4;
    cfg.serving_scale_down_idle_ms = 50; // 5 drive rounds of virtual time
    cfg.serving_max_replicas = 2;
    let p = NsmlPlatform::new(cfg).unwrap();
    let id = p.run("auto", "mnist", quick(16, 6)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let s = PlatformService::new(p);
    promote(&s, "prod", &id);
    let p = s.platform();

    // Seed the replica set (first dispatch places min_replicas = 1).
    let _ = serve_one(&s, "prod", "kim", row(0));
    assert_eq!(p.endpoint_stats("prod").0, 1);

    // A backlog deeper than the threshold, observed by a drive round,
    // grows the set — and `drive` also flushes the batches.
    let answered = Arc::new(Mutex::new(0usize));
    for i in 0..8 {
        let done = answered.clone();
        p.serve_enqueue(
            "prod",
            "kim",
            row(i),
            Box::new(move |res| {
                res.expect("burst request served");
                *done.lock().unwrap() += 1;
            }),
        )
        .unwrap();
    }
    p.drive_round(1).unwrap();
    assert_eq!(p.endpoint_stats("prod").0, 2, "queue depth 8 >= 4 scales up");
    // Partial batches may still be waiting out max_wait_ms; force the
    // flush so the idle clock below starts from a clean queue.
    p.pump_serving(true);
    wait_until("the burst to answer", || *answered.lock().unwrap() == 8);

    // Sustained idle (no queued or in-flight work) shrinks back to the
    // floor, one step per round once 50 virtual ms have accumulated.
    for _ in 0..20 {
        p.drive_round(1).unwrap();
        if p.endpoint_stats("prod").0 == 1 {
            break;
        }
    }
    assert_eq!(p.endpoint_stats("prod").0, 1, "idle endpoint returns to min_replicas");

    // Both moves were published as ReplicaScaled bus events.
    let scaled = p.events.bus().read_since(
        0,
        0,
        &nsml::events::EventFilter { kind: Some("replica".into()), ..Default::default() },
    );
    let counts: Vec<u64> = scaled
        .events
        .iter()
        .map(|e| match &e.kind {
            nsml::events::EventKind::ReplicaScaled { replicas, .. } => *replicas,
            other => panic!("{:?}", other),
        })
        .collect();
    assert_eq!(counts, vec![2, 1], "one scale-up then one scale-down: {:?}", counts);
}

// ---------------------------------------------------------------------
// Per-tenant QPS quotas
// ---------------------------------------------------------------------

#[test]
fn qps_quota_rejects_with_machine_readable_envelope() {
    let Some((s, id)) = trained_service("qps") else { return };
    promote(&s, "prod", &id);
    s.platform()
        .tenancy
        .registry
        .set_quota("throttled", TenantQuota { max_qps: 2, ..TenantQuota::default() });

    // Two requests inside one virtual second pass; the third bounces.
    for _ in 0..2 {
        let (_, _, probs) = serve_one(&s, "prod", "throttled", row(1));
        assert_eq!(probs.len(), 10);
    }
    let resp = s.dispatch(ApiRequest::ServeInfer {
        endpoint: "prod".into(),
        user: "throttled".into(),
        x: row(1),
    });
    let error = match resp {
        ApiResponse::Error { error } => error,
        other => panic!("expected quota rejection, got {:?}", other),
    };
    assert_eq!(error.code, ErrorCode::FailedPrecondition);
    assert!(
        error.message.contains("throttled") && error.message.contains('2'),
        "rejection names the user and the limit: {}",
        error.message
    );
    // The envelope is machine-readable on the wire.
    let wire = ApiResponse::Error { error }.to_json().to_string();
    let j = nsml::util::json::parse(&wire).unwrap();
    assert_eq!(j.get("kind").unwrap().as_str(), Some("error"));
    assert_eq!(
        j.at(&["data", "error", "code"]).unwrap().as_str(),
        Some("failed_precondition"),
        "{}",
        wire
    );

    // Other tenants are unaffected.
    let (_, _, probs) = serve_one(&s, "prod", "someone-else", row(2));
    assert_eq!(probs.len(), 10);

    // Rejections are not counted against the window: one virtual
    // second later the throttled user has a full budget again.
    s.platform().sim.advance(1_000);
    for _ in 0..2 {
        let (_, _, probs) = serve_one(&s, "prod", "throttled", row(1));
        assert_eq!(probs.len(), 10);
    }
}

// ---------------------------------------------------------------------
// QPS sliding window at the window boundary (property test)
// ---------------------------------------------------------------------

#[test]
fn qps_sliding_window_is_exact_at_window_boundaries() {
    use nsml::tenancy::TenantRegistry;
    // Seeded shapes: quota size, inter-request gap, and where the
    // burst sits relative to a 1-second mark all vary.
    for seed in 0..24u64 {
        let max_qps = 1 + (seed % 7) as u32;
        let step = 1 + (seed % 20);
        let edge = 1_000 * (1 + seed % 5);
        let reg = TenantRegistry::new(TenantQuota { max_qps, ..TenantQuota::default() });
        // Exactly max_qps strictly-increasing timestamps straddling
        // `edge`, total span well inside one window.
        let t0 = edge.saturating_sub(step * (max_qps as u64 / 2)).max(1);
        let stamps: Vec<u64> = (0..max_qps as u64).map(|i| t0 + i * step).collect();
        for (i, &t) in stamps.iter().enumerate() {
            assert!(
                reg.try_request("burst", t).is_ok(),
                "seed {}: request {}/{} at {} ms falsely rejected",
                seed,
                i + 1,
                max_qps,
                t
            );
        }
        // A fixed-bucket counter would have reset at the 1-second mark
        // and over-admitted; the sliding window holds the line.
        let t_last = *stamps.last().unwrap();
        assert_eq!(reg.try_request("burst", t_last).unwrap_err(), max_qps, "seed {}", seed);
        // The rejection consumed no budget, and the burst's first
        // request ages out exactly one window later: one slot frees,
        // no more.
        let freed = t0 + 1_000;
        assert!(
            reg.try_request("burst", freed).is_ok(),
            "seed {}: a slot must free exactly 1000 ms after the first admit",
            seed
        );
        assert!(reg.try_request("burst", freed).is_err(), "seed {}: only one slot freed", seed);
        // One ms before that, nothing had aged out yet.
        let reg2 = TenantRegistry::new(TenantQuota { max_qps, ..TenantQuota::default() });
        for &t in &stamps {
            reg2.try_request("burst", t).unwrap();
        }
        assert!(
            reg2.try_request("burst", t0 + 999).is_err(),
            "seed {}: the window is exactly 1000 ms wide",
            seed
        );
        // Other tenants never share the burst's window.
        assert!(reg.try_request("bystander", t_last).is_ok(), "seed {}", seed);
    }
}

// ---------------------------------------------------------------------
// Batcher flush-policy invariants (property test)
// ---------------------------------------------------------------------

#[test]
fn batcher_invariants_hold_under_arbitrary_arrivals() {
    // Deterministic LCG arrivals over 8 (max_batch, max_wait) shapes;
    // drive ticks advance virtual time 10 ms like the daemon loop.
    for seed in 0..8u64 {
        let max_batch = 1 + (seed as usize % 7);
        let max_wait = 10 * (1 + seed % 4);
        let q = ServingQueue::new(max_batch, max_wait);
        let mut state = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as usize
        };

        fn check_batch(
            seed: u64,
            max_batch: usize,
            max_wait: u64,
            batch: Vec<PendingInfer>,
            now: u64,
            delivered: &mut HashMap<u64, u64>,
            forced: bool,
        ) {
            let n = batch.len();
            assert!(n <= max_batch, "seed {}: batch of {} > {}", seed, n, max_batch);
            for req in batch {
                let id = req.x[0] as u64;
                assert!(
                    delivered.insert(id, now).is_none(),
                    "seed {}: request {} delivered twice",
                    seed,
                    id
                );
                if !forced {
                    assert!(
                        now - req.enqueued_at_ms <= max_wait,
                        "seed {}: request {} waited {} ms past enqueue (max_wait {})",
                        seed,
                        id,
                        now - req.enqueued_at_ms,
                        max_wait
                    );
                }
            }
        }

        let mut sent: u64 = 0;
        let mut delivered: HashMap<u64, u64> = HashMap::new();
        let mut now = 0u64;
        for tick in 0..200u64 {
            now = tick * 10;
            for _ in 0..next() % 4 {
                let ep = if next() % 2 == 0 { "a" } else { "b" };
                let id = sent;
                sent += 1;
                q.enqueue(
                    ep,
                    PendingInfer {
                        user: "u".into(),
                        x: vec![id as f32],
                        enqueued_at_ms: now,
                        reply: Box::new(|_| {}),
                        trace: None,
                    },
                );
            }
            for (_, batch) in q.take_due(now, false) {
                check_batch(seed, max_batch, max_wait, batch, now, &mut delivered, false);
            }
        }
        // Final forced flush: whatever still waits leaves now, still in
        // batch-sized chunks.
        for (_, batch) in q.take_due(now, true) {
            check_batch(seed, max_batch, max_wait, batch, now, &mut delivered, true);
        }
        let answered = delivered.len() as u64;
        assert_eq!(answered, sent, "seed {}: every request answered exactly once", seed);
        assert_eq!(q.depth(), 0);
        assert_eq!(q.stats().requests, sent);
    }
}
