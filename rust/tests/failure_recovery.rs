//! E12 (paper §4.2): "sometimes the system has no response and has been
//! recovered after a few minutes". Failure injection over a running
//! platform: nodes flap mid-training, sessions checkpoint-recover, and
//! every job still finishes with its full step count.

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::cluster::NodeId;
use nsml::session::SessionState;
use std::path::PathBuf;

fn platform() -> Option<NsmlPlatform> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = dir;
    Some(NsmlPlatform::new(cfg).unwrap())
}

#[test]
fn repeated_node_kills_never_lose_work() {
    let Some(p) = platform() else { return };
    let opts = RunOpts { total_steps: 60, checkpoint_every: 10, eval_every: 30, ..Default::default() };
    let a = p.run("chaos", "mnist", opts.clone()).unwrap();
    let b = p.run("chaos", "emotions", RunOpts { seed: 1, ..opts.clone() }).unwrap();

    // Kill whichever node hosts session A, twice, at different depths.
    for target_steps in [15u64, 35] {
        while p.sessions.get(&a).unwrap().steps_done < target_steps
            && !p.sessions.get(&a).unwrap().state.is_terminal()
        {
            p.drive(10).unwrap();
        }
        if let Some(node) = p.sessions.get(&a).unwrap().node {
            p.kill_node(node);
            // Bring it back so capacity recovers.
            p.cluster.revive_node(node);
        }
    }
    p.run_to_completion(10, 10_000).unwrap();

    for id in [&a, &b] {
        let rec = p.sessions.get(id).unwrap();
        assert_eq!(rec.state, SessionState::Done, "{}", id);
        assert_eq!(rec.steps_done, 60, "{}", id);
    }
    assert!(p.sessions.get(&a).unwrap().recoveries >= 1);
    // Checkpoint history shows the resume points.
    assert!(p.checkpoints.list(&a).len() >= 3);
}

#[test]
fn failure_plan_storm_all_sessions_finish() {
    use nsml::cluster::FailurePlan;
    let Some(p) = platform() else { return };
    let opts = RunOpts { total_steps: 40, checkpoint_every: 8, eval_every: 20, ..Default::default() };
    let mut ids = Vec::new();
    for i in 0..3 {
        ids.push(p.run("storm", "mnist", RunOpts { seed: i, ..opts.clone() }).unwrap());
    }
    // Deterministic outage schedule over virtual time: node flaps.
    let mut plan = FailurePlan::random(99, 3, 30_000, 4.0, 2_000.0);
    let mut rounds = 0;
    loop {
        rounds += 1;
        assert!(rounds < 10_000, "storm did not settle");
        let orphans = plan.step(&p.cluster);
        if !orphans.is_empty() {
            // Platform notices on the next drive (reap/requeue path).
        }
        p.drive(5).unwrap();
        p.sim.advance(500);
        let done = ids
            .iter()
            .all(|id| p.sessions.get(id).unwrap().state == SessionState::Done);
        if done {
            break;
        }
    }
    for id in &ids {
        let rec = p.sessions.get(id).unwrap();
        assert_eq!(rec.steps_done, 40, "{}", id);
    }
}

#[test]
fn scheduler_leader_failover_is_transparent_to_sessions() {
    let Some(p) = platform() else { return };
    let opts = RunOpts { total_steps: 30, checkpoint_every: 10, eval_every: 15, ..Default::default() };
    let id = p.run("lead", "mnist", opts).unwrap();
    p.drive(10).unwrap();
    // Kill the scheduler leader mid-run.
    let (leader, epoch) = p.election.leader().unwrap();
    p.election.kill(leader);
    p.sim.advance(20);
    p.run_to_completion(10, 10_000).unwrap();
    // Session unaffected; a new leader rules a later epoch.
    assert_eq!(p.sessions.get(&id).unwrap().state, SessionState::Done);
    let (new_leader, new_epoch) = p.election.leader().unwrap();
    assert_ne!(new_leader, leader);
    assert!(new_epoch > epoch);
}

#[test]
fn permanent_node_loss_replaces_on_surviving_nodes() {
    // Unlike the flap tests, the node never comes back: the session must
    // finish on the remaining capacity.
    let Some(p) = platform() else { return };
    let opts = RunOpts { total_steps: 40, checkpoint_every: 10, eval_every: 20, ..Default::default() };
    let id = p.run("reap", "mnist", opts).unwrap();
    p.drive(10).unwrap();
    let node = p.sessions.get(&id).unwrap().node.unwrap();
    p.kill_node(node);
    p.run_to_completion(10, 10_000).unwrap();
    let rec = p.sessions.get(&id).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 40);
    // It finished on a different node.
    assert_ne!(rec.node, Some(node));
    assert_eq!(p.cluster.alive_count(), 2);
}
