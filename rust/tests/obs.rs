//! Gating suite for the observability spine: the log-bucket histogram
//! partitions sampled values into exactly one bucket each and bounds
//! its quantile error to one bucket width (property test over seeded
//! LCG workloads), the Prometheus text exposition is well-formed
//! (one `# TYPE` per family, parseable series lines, escaped labels,
//! monotone cumulative `le` buckets closed by `+Inf`), and one HTTP
//! inference through the live daemon + web front end yields a
//! connected, time-ordered span chain retrievable under its
//! `X-Trace-Id` — with `/metrics` converging on the dispatch, web,
//! serving, and durability metric families.

use nsml::api::{
    ApiRequest, ApiResponse, DaemonOpts, NsmlPlatform, PlatformConfig, PlatformService, RunOpts,
};
use nsml::obs::{bucket_bound, bucket_index, MetricsRegistry};
use nsml::web::{serve_with, ServeOpts, WebState};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// Histogram bucket boundaries (property test)
// ---------------------------------------------------------------------

#[test]
fn histogram_buckets_partition_sampled_values() {
    // Seeded LCG workloads, log-uniform over ~7 decades of latency —
    // strictly inside the bucket table so no sample hits the clamps.
    for seed in 0..16u64 {
        let mut state = seed.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
        let mut next = move || {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            (state >> 33) as u64
        };
        let reg = MetricsRegistry::new(true);
        let h = reg.histogram("nsml_prop_ms", &[]);
        let mut values: Vec<f64> = Vec::new();
        for _ in 0..200 {
            let e = (next() % 2400) as f64 / 100.0; // exponent in [0, 24)
            values.push(0.002 * 2f64.powf(e));
        }
        for &v in &values {
            // Every value lands in exactly one half-open bucket:
            // bound(i-1) < v <= bound(i).
            let i = bucket_index(v);
            assert!(v <= bucket_bound(i), "seed {}: v={} above bucket {} bound", seed, v, i);
            assert!(
                i == 0 || v > bucket_bound(i - 1),
                "seed {}: v={} also fits bucket {}",
                seed,
                v,
                i - 1
            );
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 200, "seed {}", seed);
        assert_eq!(
            snap.buckets.iter().sum::<u64>(),
            200,
            "seed {}: each sample counted in exactly one bucket",
            seed
        );
        // The quantile estimate is the upper bound of the rank's
        // bucket: at least the exact order statistic, and within one
        // bucket width (a factor of two) above it.
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for &q in &[0.5, 0.9, 0.95, 0.99, 1.0] {
            let rank = ((q * 200.0).ceil() as usize).clamp(1, 200);
            let exact = values[rank - 1];
            let est = snap.quantile(q);
            assert!(
                est >= exact - 1e-12 && est < 2.0 * exact,
                "seed {} q={}: estimate {} not within one bucket of exact {}",
                seed,
                q,
                est,
                exact
            );
        }
    }
}

// ---------------------------------------------------------------------
// Prometheus text exposition (format 0.0.4)
// ---------------------------------------------------------------------

#[test]
fn prometheus_exposition_is_well_formed() {
    let reg = MetricsRegistry::new(true);
    reg.counter("nsml_fmt_total", &[("user", "kim"), ("verb", "run")]).add(3);
    reg.counter("nsml_fmt_total", &[("user", "lee"), ("verb", "run")]).inc();
    reg.gauge("nsml_fmt_gauge", &[("label", "wei\"rd\\back\nline")]).set(2.5);
    let h = reg.histogram("nsml_fmt_ms", &[("route", "/")]);
    for v in [0.5, 1.0, 4.0, 4.0, 900.0] {
        h.record(v);
    }
    let text = reg.render_prometheus();

    // Every line is either `# TYPE <family> <kind>` (once per family)
    // or `<series> <float>`.
    let mut families: Vec<String> = Vec::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split(' ');
            let fam = it.next().unwrap().to_string();
            let kind = it.next().unwrap_or("");
            assert!(matches!(kind, "counter" | "gauge" | "histogram"), "{}", line);
            assert!(!families.contains(&fam), "family {} declared twice", fam);
            families.push(fam);
        } else {
            let (series, value) =
                line.rsplit_once(' ').unwrap_or_else(|| panic!("no value on line: {}", line));
            value.parse::<f64>().unwrap_or_else(|_| panic!("unparseable value: {}", line));
            if series.contains('{') {
                assert!(series.ends_with('}'), "unbalanced labels: {}", line);
            }
        }
    }
    assert_eq!(
        families,
        vec!["nsml_fmt_total", "nsml_fmt_gauge", "nsml_fmt_ms"],
        "one TYPE line per family, counters then gauges then histograms"
    );

    // Label values escape backslash, double-quote, and newline; pairs
    // render in sorted key order.
    assert!(text.contains(r#"label="wei\"rd\\back\nline""#), "{}", text);
    assert!(text.contains("nsml_fmt_total{user=\"kim\",verb=\"run\"} 3"), "{}", text);

    // Cumulative `le` buckets are monotone and close with `+Inf` at
    // the total count; `_sum` and `_count` series follow.
    let bucket_lines: Vec<&str> =
        text.lines().filter(|l| l.starts_with("nsml_fmt_ms_bucket")).collect();
    assert!(bucket_lines.len() >= 2, "{}", text);
    let mut last = 0.0f64;
    for l in &bucket_lines {
        assert!(l.contains("le=\""), "{}", l);
        let v: f64 = l.rsplit_once(' ').unwrap().1.parse().unwrap();
        assert!(v >= last, "cumulative buckets must be monotone: {}", l);
        last = v;
    }
    assert!(bucket_lines.last().unwrap().contains("le=\"+Inf\""), "{}", text);
    assert_eq!(last, 5.0, "+Inf bucket equals the total count");
    assert!(text.contains("nsml_fmt_ms_count{route=\"/\"} 5"), "{}", text);
    assert!(text.contains("nsml_fmt_ms_sum{route=\"/\"}"), "{}", text);
}

// ---------------------------------------------------------------------
// Trace propagation: one HTTP inference through the daemon
// ---------------------------------------------------------------------

/// Read exactly one HTTP/1.1 response off a keep-alive socket; returns
/// `(head, body)` and leaves any extra bytes in `buf`.
fn read_response(stream: &mut TcpStream, buf: &mut Vec<u8>) -> (String, String) {
    fn find(hay: &[u8], needle: &[u8]) -> Option<usize> {
        hay.windows(needle.len()).position(|w| w == needle)
    }
    let header_end = loop {
        if let Some(pos) = find(buf, b"\r\n\r\n") {
            break pos + 4;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read headers");
        assert!(n > 0, "server closed the socket mid-response");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).to_string();
    let body_len = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            k.eq_ignore_ascii_case("content-length").then(|| v.trim().parse::<usize>().unwrap())
        })
        .unwrap_or(0);
    while buf.len() < header_end + body_len {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk).expect("read body");
        assert!(n > 0, "server closed the socket mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    let body = String::from_utf8_lossy(&buf[header_end..header_end + body_len]).to_string();
    buf.drain(..header_end + body_len);
    (head, body)
}

#[test]
fn one_http_inference_yields_a_connected_trace() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = dir;
    let p = NsmlPlatform::new(cfg).unwrap();
    let opts =
        RunOpts { total_steps: 16, eval_every: 8, checkpoint_every: 8, ..Default::default() };
    let id = p.run("obs", "mnist", opts).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let s = PlatformService::new(p);
    match s.dispatch(ApiRequest::Promote {
        endpoint: "prod".into(),
        action: "promote".into(),
        session: Some(id),
    }) {
        ApiResponse::Endpoint { .. } => {}
        other => panic!("promote: {:?}", other),
    }

    // The `nsml serve` deployment shape: daemon drive loop on this
    // thread, pooled HTTP front end with the service handle AND the
    // observability spine attached.
    let platform = s.platform();
    let obs = platform.obs.clone();
    let (handle, rx) = nsml::api::service_channel();
    let state = WebState {
        sessions: platform.sessions.clone(),
        leaderboard: platform.leaderboard.clone(),
        cluster: Some(platform.cluster.clone()),
        events: platform.events.clone(),
        api: Some(handle.clone()),
        obs: Some(obs.clone()),
    };
    drop(handle);
    let srv = serve_with(state, 0, ServeOpts { workers: 2, ..ServeOpts::default() }).unwrap();
    let port = srv.port();
    let daemon_opts = DaemonOpts { idle_wait: Duration::from_millis(2), ..DaemonOpts::default() };
    let stop = daemon_opts.stop.clone();

    let client = std::thread::spawn(move || {
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut buf = Vec::new();

        // One inference under an explicit trace id.
        let x: Vec<String> = (0..144).map(|i| format!("{}", (i % 97) as f32 / 97.0)).collect();
        let body = format!("{{\"user\":\"kim\",\"x\":[{}]}}", x.join(","));
        write!(
            stream,
            "POST /api/v1/endpoints/prod/infer HTTP/1.1\r\nHost: t\r\nX-Trace-Id: obs-e2e-1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .unwrap();
        let (head, resp) = read_response(&mut stream, &mut buf);
        assert!(head.starts_with("HTTP/1.1 200"), "{}\n{}", head, resp);
        assert!(resp.contains("\"kind\":\"served\""), "{}", resp);
        assert!(head.contains("X-Trace-Id: obs-e2e-1"), "trace id echoed back: {}", head);

        // The span chain is retrievable over the same wire surface.
        write!(stream, "GET /api/v1/trace/obs-e2e-1 HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
        let (head, trace_body) = read_response(&mut stream, &mut buf);
        assert!(head.starts_with("HTTP/1.1 200"), "{}\n{}", head, trace_body);
        for needle in [
            "\"kind\":\"trace\"",
            "serving.enqueue",
            "serving.flush",
            "http POST /api/v1/endpoints/prod/infer",
        ] {
            assert!(trace_body.contains(needle), "missing {} in: {}", needle, trace_body);
        }

        // /metrics converges on every layer's families once the pump
        // has consumed the InferServed event (a later drive round).
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            write!(stream, "GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let (head, metrics) = read_response(&mut stream, &mut buf);
            assert!(head.starts_with("HTTP/1.1 200"), "{}", head);
            let wanted = [
                "nsml_http_requests_total",  // web
                "nsml_dispatch_ms",          // service dispatch
                "nsml_serving_latency_ms",   // serving data path
                "nsml_serving_latency_p99_ms", // windowed gauge (autoscaler feed)
                "nsml_wal_append_ms",        // durability
                "nsml_cluster_utilization",  // executor/cluster rollup
            ];
            if wanted.iter().all(|n| metrics.contains(n)) {
                break;
            }
            assert!(Instant::now() < deadline, "metrics never converged:\n{}", metrics);
            std::thread::sleep(Duration::from_millis(20));
        }
        stop.store(true, Ordering::SeqCst);
    });
    s.run_daemon(&rx, &daemon_opts).unwrap();
    client.join().unwrap();
    srv.shutdown();

    // The recorded chain is connected (ingress + queue + flush at
    // minimum) and time-ordered on the platform clock.
    let spans = obs.traces.get("obs-e2e-1");
    assert!(spans.len() >= 3, "expected a multi-span chain: {:?}", spans);
    for w in spans.windows(2) {
        assert!(w[0].at_ms <= w[1].at_ms, "span timestamps must be monotone: {:?}", spans);
    }
    let names: Vec<&str> = spans.iter().map(|sp| sp.name.as_str()).collect();
    assert!(names.contains(&"serving.enqueue"), "{:?}", names);
    assert!(names.contains(&"serving.flush"), "{:?}", names);
    assert!(
        names.iter().any(|n| n.starts_with("http POST /api/v1/endpoints/prod/infer")),
        "{:?}",
        names
    );
    assert!(
        spans.iter().any(|sp| sp.source == "web") && spans.iter().any(|sp| sp.source == "serving"),
        "{:?}",
        spans
    );
    // The batch-execution span lands from the replica worker thread a
    // beat after the reply; poll briefly rather than racing it.
    let deadline = Instant::now() + Duration::from_secs(10);
    while !obs.traces.get("obs-e2e-1").iter().any(|sp| sp.name == "serving.batch") {
        assert!(Instant::now() < deadline, "serving.batch span never recorded");
        std::thread::sleep(Duration::from_millis(2));
    }
}
