//! Integration: event-sourced durability end to end — WAL persistence
//! across dirty process exits (pure-logic), crash recovery over a live
//! platform (snapshot + WAL-tail replay must reproduce pre-crash
//! state exactly), mid-flight requeue after recovery, and GC safety
//! (a live session's checkpoint chain is never swept).

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::durability::Wal;
use nsml::session::SessionState;
use std::io::Write;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn tmp_state(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nsml-dur-it-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A platform over `state` with durability on (the config default).
fn platform(state: &PathBuf) -> Option<NsmlPlatform> {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = artifacts()?;
    cfg.state_dir = Some(state.clone());
    Some(NsmlPlatform::new(cfg).unwrap())
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 4).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

// -------------------------------------------------------------------
// Pure-logic: the WAL file through its public API (no artifacts).
// -------------------------------------------------------------------

#[test]
fn wal_survives_dirty_exit_and_truncates_torn_tail() {
    use nsml::events::{Event, EventKind, Level};
    let dir = tmp_state("wal");
    let path = dir.join("wal.log");
    let ev = |seq: u64| Event {
        seq,
        at_ms: seq * 10,
        level: Level::Info,
        source: "session".into(),
        subject: "kim/mnist/1".into(),
        kind: EventKind::StateChanged { from: "x".into(), to: "running".into(), step: seq },
    };
    {
        let (mut wal, scan) = Wal::open(&path, 64).unwrap();
        assert!(scan.events.is_empty());
        for i in 0..10 {
            wal.append(&ev(i)).unwrap();
        }
    } // dropped with 10 unsynced appends — a dirty exit
    // Simulate a crash mid-append on top of the valid prefix.
    let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
    f.write_all(&999u32.to_le_bytes()).unwrap();
    f.write_all(b"torn").unwrap();
    drop(f);

    let (wal, scan) = Wal::open(&path, 64).unwrap();
    assert_eq!(scan.events.len(), 10, "every whole record survives");
    assert!(scan.truncated_bytes > 0, "the torn tail was cut off");
    assert_eq!(wal.last_seq(), Some(9));
    assert_eq!(scan.events[7], ev(7));
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------------------
// Crash recovery over a live platform (artifacts-gated).
// -------------------------------------------------------------------

/// The ISSUE.md acceptance scenario: drive sessions to completion,
/// drop the platform WITHOUT a clean save, reload over the same state
/// dir, and assert sessions, board ranks, quotas and GPU-second usage
/// all match the pre-crash capture. The only clean save is one early
/// snapshot taken while both sessions were still running — everything
/// after it reaches the second process through the WAL tail alone.
#[test]
fn crash_recovery_reproduces_completed_state() {
    let state = tmp_state("crash");
    let Some(p) = platform(&state) else { return };

    // Two tenants with distinct quotas (quotas travel in the
    // snapshot, not the WAL — they must come back too).
    p.tenancy.registry.update_quota("kim", |q| {
        q.max_gpus = 3;
        q.weight = 2;
    });
    let kim = p.run("kim", "mnist", quick(20, 0)).unwrap();
    let lee = p.run("lee", "mnist", quick(24, 1)).unwrap();

    // The one clean save: a mid-flight snapshot. Both sessions are in
    // state.json, but none of their training history is.
    p.drive(4).unwrap();
    p.save_state().unwrap();

    // Everything from here on lives only in the WAL.
    p.run_to_completion(6, 10_000).unwrap();

    // Pre-crash capture. Per-step train_loss points are record-only
    // by design (publishing one event per step would flood the bus),
    // so the durable contract covers state, steps, best metric, and
    // the published series: eval_loss and the task metric.
    let pre: Vec<_> = [&kim, &lee]
        .iter()
        .map(|id| {
            let r = p.sessions.get(id).unwrap();
            (
                r.spec.id.clone(),
                r.state,
                r.steps_done,
                r.best_metric,
                r.metrics.series("accuracy"),
                r.metrics.series("eval_loss").len(),
            )
        })
        .collect();
    let pre_ranks =
        (p.leaderboard.rank_of("mnist", &kim), p.leaderboard.rank_of("mnist", &lee));
    let far = 100_000_000;
    let pre_usage =
        (p.tenancy.accountant.usage_at("kim", far), p.tenancy.accountant.usage_at("lee", far));
    assert!(pre_usage.0 > 0.0 && pre_usage.1 > 0.0, "both sessions burned GPU-seconds");

    drop(p); // crash: no save_state

    let p2 = platform(&state).unwrap();
    for (id, state_pre, steps, best, accuracy, n_eval) in &pre {
        let r = p2.sessions.get(id).unwrap();
        assert_eq!(r.state, *state_pre, "{}", id);
        assert_eq!(r.steps_done, *steps, "{}", id);
        assert_eq!(r.best_metric, *best, "{}", id);
        assert_eq!(&r.metrics.series("accuracy"), accuracy, "{}: series replayed", id);
        assert_eq!(r.metrics.series("eval_loss").len(), *n_eval, "{}", id);
    }
    assert_eq!(p2.leaderboard.rank_of("mnist", &kim), pre_ranks.0);
    assert_eq!(p2.leaderboard.rank_of("mnist", &lee), pre_ranks.1);
    let q = p2.tenancy.registry.quota_of("kim");
    assert_eq!(q.max_gpus, 3);
    assert_eq!(q.weight, 2);
    assert!((p2.tenancy.accountant.usage_at("kim", far) - pre_usage.0).abs() < 1e-9);
    assert!((p2.tenancy.accountant.usage_at("lee", far) - pre_usage.1).abs() < 1e-9);

    // Post-snapshot checkpoints were re-indexed from the object store
    // and their params still load — recovery is inference-ready.
    let latest = p2.checkpoints.latest(&kim).expect("checkpoint index rebuilt");
    assert!(p2.checkpoints.load_params(&latest).is_ok());
    let x = nsml::runtime::TensorData::f32(vec![0.5; 64 * 144], &[64, 144]);
    assert_eq!(p2.infer(&kim, &x).unwrap().len(), 640);

    // Recovery must retire the replayed WAL behind a fresh baseline:
    // a third boot over the same dir sees the identical world, not a
    // double-applied one (usage counted twice, metric points duplicated).
    drop(p2);
    let p3 = platform(&state).unwrap();
    for (id, state_pre, steps, best, accuracy, n_eval) in &pre {
        let r = p3.sessions.get(id).unwrap();
        assert_eq!(r.state, *state_pre, "{}", id);
        assert_eq!(r.steps_done, *steps, "{}", id);
        assert_eq!(r.best_metric, *best, "{}", id);
        assert_eq!(&r.metrics.series("accuracy"), accuracy, "{}: no double replay", id);
        assert_eq!(r.metrics.series("eval_loss").len(), *n_eval, "{}", id);
    }
    assert!((p3.tenancy.accountant.usage_at("kim", far) - pre_usage.0).abs() < 1e-9);
    assert!((p3.tenancy.accountant.usage_at("lee", far) - pre_usage.1).abs() < 1e-9);

    let _ = std::fs::remove_dir_all(&state);
}

/// A crash with a session mid-flight: recovery requeues it (the GPUs
/// and containers of the dead process are gone) and it trains through
/// to done on the new platform.
#[test]
fn crash_mid_flight_requeues_and_completes() {
    let state = tmp_state("midflight");
    let Some(p) = platform(&state) else { return };
    let id = p.run("kim", "mnist", quick(40, 2)).unwrap();
    p.save_state().unwrap(); // the session reaches the snapshot queued/running
    p.drive(5).unwrap();
    p.drive(5).unwrap(); // partial progress, WAL-only
    assert!(!p.sessions.get(&id).unwrap().state.is_terminal());
    drop(p); // crash

    let p2 = platform(&state).unwrap();
    let rec = p2.sessions.get(&id).expect("session survived the crash");
    assert!(
        !rec.state.is_terminal(),
        "mid-flight work is requeued, not invented as finished: {:?}",
        rec.state
    );
    p2.run_to_completion(8, 10_000).unwrap();
    let rec = p2.sessions.get(&id).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 40);
    assert!(rec.best_metric.is_some());
    assert_eq!(p2.leaderboard.rank_of("mnist", &id), Some(1));

    let _ = std::fs::remove_dir_all(&state);
}

/// GC safety: orphaned blobs are swept, but nothing referenced by a
/// live session's checkpoint chain (params or metadata records) ever
/// is — inference still works after a sweep, and the sweep's bytes
/// are attributed to the owning tenant.
#[test]
fn gc_sweeps_orphans_but_never_a_live_checkpoint_chain() {
    let state = tmp_state("gc");
    let Some(p) = platform(&state) else { return };
    let id = p.run("kim", "mnist", quick(20, 3)).unwrap();
    p.run_to_completion(10, 10_000).unwrap();
    let chain = p.checkpoints.list(&id);
    assert!(!chain.is_empty());

    // Plant orphans: an unreferenced blob now, and garbage that looks
    // nothing like a checkpoint record.
    let orphan = p.objects.put(b"orphaned-params-from-a-deleted-trial").unwrap();
    p.objects.put(b"{\"not\": \"a checkpoint\"}").unwrap();

    let report = p.gc().unwrap();
    assert!(report.swept_objects >= 2, "{:?}", report);
    assert!(!p.objects.has(&orphan), "the orphan is gone");
    for ck in &chain {
        assert!(p.objects.has(&ck.params), "live params survived: step {}", ck.step);
        assert!(p.checkpoints.load_params(ck).is_ok());
    }
    let x = nsml::runtime::TensorData::f32(vec![0.5; 64 * 144], &[64, 144]);
    assert_eq!(p.infer(&id, &x).unwrap().len(), 640);
    assert!(p.tenancy.registry.storage_bytes_of("kim") > 0, "checkpoint bytes attributed");

    // Idempotent: a second sweep finds nothing more to remove.
    let again = p.gc().unwrap();
    assert_eq!(again.swept_objects, 0, "{:?}", again);
    assert_eq!(again.live_objects, report.live_objects);

    let _ = std::fs::remove_dir_all(&state);
}

/// Serve one row through the facade's micro-batcher, waiting out the
/// executor serve lane's asynchronous reply.
fn serve_sync(p: &NsmlPlatform, endpoint: &str, x: Vec<f32>) -> Vec<f32> {
    let slot = Arc::new(Mutex::new(None));
    let out = slot.clone();
    p.serve_enqueue(
        endpoint,
        "kim",
        x,
        Box::new(move |r| {
            *out.lock().unwrap() = Some(r.expect("serve failed"));
        }),
    )
    .unwrap();
    p.pump_serving(true);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    loop {
        if let Some(row) = slot.lock().unwrap().take() {
            return row.probs;
        }
        assert!(std::time::Instant::now() < deadline, "serve reply never fired");
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
}

/// Serving endpoints are durable: a promote → promote → rollback
/// history that only ever reached the WAL (the single clean snapshot
/// predates it) comes back after a dirty exit — active cursor and full
/// version history — and the recovered endpoint serves bitwise the
/// same output. GC, before and after the crash, never sweeps a
/// checkpoint that any endpoint version pins: rollback targets stay
/// loadable, not just the active version.
#[test]
fn endpoints_survive_crash_and_gc_never_sweeps_pinned_params() {
    let state = tmp_state("endpoints");
    let Some(p) = platform(&state) else { return };
    let s1 = p.run("kim", "mnist", quick(16, 7)).unwrap();
    let s2 = p.run("kim", "mnist", quick(16, 8)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    p.save_state().unwrap(); // baseline snapshot: no endpoints yet

    // Everything serving-related reaches the next process via the WAL.
    let v1 = p.promote_endpoint("prod", &s1).unwrap();
    let v2 = p.promote_endpoint("prod", &s2).unwrap();
    p.rollback_endpoint("prod").unwrap(); // active: v1, v2 kept in history
    let x: Vec<f32> = (0..144).map(|i| (i % 7) as f32 / 7.0).collect();
    let pre = serve_sync(&p, "prod", x.clone());
    assert_eq!(pre.len(), 10);

    // Pre-crash sweep: orphans go, both pinned versions stay.
    let orphan = p.objects.put(b"orphan-before-the-crash").unwrap();
    p.gc().unwrap();
    assert!(!p.objects.has(&orphan));
    assert!(p.objects.has(&v1.object) && p.objects.has(&v2.object));

    drop(p); // crash: no save_state

    let p2 = platform(&state).unwrap();
    let ep = p2.endpoints.get("prod").expect("endpoint replayed from the WAL");
    assert_eq!(ep.versions.len(), 2, "full history recovered");
    assert_eq!(ep.active_version().version, 1, "rollback cursor recovered");
    assert_eq!(ep.active_version().session, s1);
    assert_eq!(ep.versions[1].session, s2);
    assert_eq!(serve_sync(&p2, "prod", x.clone()), pre, "recovered endpoint serves the same bits");

    // Post-crash sweep: the non-active v2 is exactly the object a
    // liveness-only GC would lose — it must survive for rollforward.
    let orphan = p2.objects.put(b"orphan-after-the-crash").unwrap();
    p2.gc().unwrap();
    assert!(!p2.objects.has(&orphan));
    assert!(p2.objects.has(&v1.object), "active version pinned");
    assert!(p2.objects.has(&v2.object), "rollback target pinned");
    let fwd = p2.rollforward_endpoint("prod").unwrap();
    assert_eq!(fwd.version, 2);
    assert_eq!(serve_sync(&p2, "prod", x.clone()).len(), 10, "v2 params still load after GC");

    // The rollforward was WAL-only too; a third boot agrees.
    drop(p2);
    let p3 = platform(&state).unwrap();
    let ep = p3.endpoints.get("prod").unwrap();
    assert_eq!(ep.active_version().version, 2);
    assert_eq!(ep.versions.len(), 2);

    let _ = std::fs::remove_dir_all(&state);
}

/// `durability_status` tells the truth over a live platform: records
/// accumulate in the WAL segment, save_state snapshots and rotates.
#[test]
fn durability_status_tracks_wal_and_snapshots() {
    let state = tmp_state("status");
    let Some(p) = platform(&state) else { return };
    let _ = p.run("kim", "mnist", quick(16, 4)).unwrap();
    p.run_to_completion(8, 10_000).unwrap();
    let stats = p.durability_status().expect("durability on");
    assert!(stats.wal_records > 0, "training appended durable records");
    assert!(stats.wal_bytes > 0);
    assert_eq!(stats.wal_dropped, 0);

    let before = p.durability_status().unwrap().snapshots;
    p.save_state().unwrap(); // snapshot-on-demand
    let stats = p.durability_status().unwrap();
    assert_eq!(stats.snapshots, before + 1);
    assert_eq!(stats.wal_records, 0, "segment rotated by the snapshot");
    assert_eq!(stats.records_since_snapshot, 0);

    let _ = std::fs::remove_dir_all(&state);
}
