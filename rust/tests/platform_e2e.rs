//! Integration: the full platform across subsystems (experiment E1) plus
//! persistence and the web API over live platform state.

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::session::SessionState;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn platform() -> Option<NsmlPlatform> {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = artifacts()?;
    Some(NsmlPlatform::new(cfg).unwrap())
}

fn quick(steps: u64, seed: u64) -> RunOpts {
    RunOpts {
        total_steps: steps,
        eval_every: (steps / 2).max(1),
        checkpoint_every: (steps / 2).max(1),
        seed,
        ..Default::default()
    }
}

#[test]
fn all_four_alpha_tasks_complete_and_rank() {
    let Some(p) = platform() else { return };
    let mut ids = Vec::new();
    for (i, ds) in ["mnist", "emotions", "movie-reviews", "faces"].iter().enumerate() {
        ids.push((ds.to_string(), p.run("alpha", ds, quick(16, i as u64)).unwrap()));
    }
    p.run_to_completion(8, 10_000).unwrap();
    for (ds, id) in &ids {
        let rec = p.sessions.get(id).unwrap();
        assert_eq!(rec.state, SessionState::Done, "{}", ds);
        assert!(rec.best_metric.is_some(), "{}", ds);
        assert_eq!(p.leaderboard.rank_of(ds, id), Some(1), "{}", ds);
    }
    // Every container stopped, every GPU released.
    assert!(p.containers.running().is_empty());
    let (total, free) = p.cluster.gpu_totals();
    assert_eq!(total, free);
}

#[test]
fn persistence_round_trip_across_platform_restart() {
    let Some(art) = artifacts() else { return };
    let state = std::env::temp_dir().join(format!("nsml-e2e-state-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&state);

    let id = {
        let mut cfg = PlatformConfig::test_default();
        cfg.artifacts_dir = art.clone();
        cfg.state_dir = Some(state.clone());
        let p = NsmlPlatform::new(cfg).unwrap();
        let id = p.run("kim", "mnist", quick(20, 0)).unwrap();
        p.run_to_completion(10, 10_000).unwrap();
        p.save_state().unwrap();
        id
    };

    // "Restart" the platform over the same state dir.
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = art;
    cfg.state_dir = Some(state.clone());
    let p2 = NsmlPlatform::new(cfg).unwrap();
    let rec = p2.sessions.get(&id).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert!(rec.metrics.len() > 0);
    assert_eq!(p2.leaderboard.rank_of("mnist", &id), Some(1));
    // Checkpoints usable: inference works after restart.
    let x = nsml::runtime::TensorData::f32(vec![0.5; 64 * 144], &[64, 144]);
    assert_eq!(p2.infer(&id, &x).unwrap().len(), 640);

    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn web_api_serves_live_platform_state() {
    use std::io::{Read, Write};
    let Some(p) = platform() else { return };
    let id = p.run("web", "mnist", quick(10, 3)).unwrap();
    p.run_to_completion(5, 10_000).unwrap();

    // The deprecated read aliases dispatch through the service now, so
    // the fixture needs a live handle — the platform owner (this thread)
    // pumps the queries the client issues.
    let service = nsml::api::PlatformService::new(p);
    let (api, rx) = nsml::api::service_channel();
    let state = nsml::web::WebState {
        sessions: service.platform().sessions.clone(),
        leaderboard: service.platform().leaderboard.clone(),
        cluster: Some(service.platform().cluster.clone()),
        events: service.platform().events.clone(),
        api: Some(api),
        obs: None,
    };
    let srv = nsml::web::serve(state, 0).unwrap();
    let port = srv.port();

    let sid = id.clone();
    let client = std::thread::spawn(move || {
        let fetch = |path: &str| -> String {
            let mut s = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            write!(s, "GET {} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n", path).unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let dash = fetch("/");
        let api = fetch("/api/sessions");
        let board = fetch("/api/board/mnist");
        let svg = fetch(&format!("/plot/{}.svg", sid));
        (dash, api, board, svg)
    });
    // Two of the four fetches are alias routes that dispatch.
    for _ in 0..2 {
        assert!(service.serve_one(&rx));
    }
    let (dash, api, board, svg) = client.join().unwrap();
    srv.shutdown();

    assert!(dash.starts_with("HTTP/1.1 200"));
    assert!(dash.contains(&id));
    assert!(api.contains("\"state\":\"done\""), "{}", api);
    assert!(api.contains("Deprecation: true"), "{}", api);
    assert!(board.contains("\"rank\":1"), "{}", board);
    assert!(svg.contains("image/svg+xml"));
    assert!(svg.contains("train_loss"));
}

#[test]
fn web_post_api_v1_mutates_through_the_service() {
    use std::io::{Read, Write};
    let Some(p) = platform() else { return };
    let service = nsml::api::PlatformService::new(p);
    let (api, rx) = nsml::api::service_channel();
    let state = nsml::web::WebState {
        sessions: service.platform().sessions.clone(),
        leaderboard: service.platform().leaderboard.clone(),
        cluster: Some(service.platform().cluster.clone()),
        events: service.platform().events.clone(),
        api: Some(api),
        obs: None,
    };
    let srv = nsml::web::serve(state, 0).unwrap();
    let port = srv.port();

    // HTTP client on a side thread; this thread (the platform owner)
    // pumps exactly the dispatches the client issues.
    let client = std::thread::spawn(move || {
        let post = |path: &str, body: &str| -> String {
            let mut s = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            write!(
                s,
                "POST {} HTTP/1.1\r\nHost: t\r\nConnection: close\r\nContent-Length: {}\r\n\r\n{}",
                path,
                body.len(),
                body
            )
            .unwrap();
            let mut out = String::new();
            s.read_to_string(&mut out).unwrap();
            out
        };
        let run = post("/api/v1/run", r#"{"user":"web","dataset":"mnist","total_steps":10,"eval_every":5,"checkpoint_every":5}"#);
        let done = post("/api/v1/run_to_completion", r#"{"chunk":5,"max_rounds":10000}"#);
        let missing = post("/api/v1/get_session", r#"{"session":"missing"}"#);
        (run, done, missing)
    });
    // Serve the client's three dispatches, then collect its results.
    let service_thread_work = || {
        for _ in 0..3 {
            assert!(service.serve_one(&rx));
        }
    };
    service_thread_work();
    let (run, done, missing) = client.join().unwrap();
    srv.shutdown();

    assert!(run.starts_with("HTTP/1.1 200"), "{}", run);
    assert!(run.contains("\"kind\":\"submitted\""), "{}", run);
    assert!(done.starts_with("HTTP/1.1 200"), "{}", done);
    assert!(done.contains("\"kind\":\"ack\""), "{}", done);
    assert!(missing.starts_with("HTTP/1.1 404"), "{}", missing);
    assert!(missing.contains("not_found"), "{}", missing);

    // The mutation really happened on the platform.
    let sessions = service.platform().sessions.list();
    assert_eq!(sessions.len(), 1);
    assert_eq!(sessions[0].state, SessionState::Done);
    assert_eq!(sessions[0].spec.user, "web");
}

#[test]
fn web_405_includes_allow_header() {
    use std::io::{Read, Write};
    let Some(p) = platform() else { return };
    let state = nsml::web::WebState {
        sessions: p.sessions.clone(),
        leaderboard: p.leaderboard.clone(),
        cluster: Some(p.cluster.clone()),
        events: p.events.clone(),
        api: None,
        obs: None,
    };
    let srv = nsml::web::serve(state, 0).unwrap();
    let mut s = std::net::TcpStream::connect(("127.0.0.1", srv.port())).unwrap();
    write!(s, "PUT / HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    srv.shutdown();
    assert!(out.starts_with("HTTP/1.1 405"), "{}", out);
    assert!(out.contains("Allow: GET, POST"), "{}", out);
}

#[test]
fn gpu_requests_respected_and_fragmentation_visible() {
    let Some(p) = platform() else { return };
    // 3 nodes x 4 GPUs: 3 x 3-GPU jobs leave 1 GPU free per node (3 total
    // free) — yet a 2-GPU job still fits; a 4-GPU job must queue.
    for i in 0..3 {
        let mut o = quick(1_000, i);
        o.gpus = 3;
        p.run("frag", "mnist", o).unwrap();
    }
    let mut small = quick(1_000, 9);
    small.gpus = 1;
    let small_id = p.run("frag", "mnist", small).unwrap();
    let mut big = quick(1_000, 10);
    big.gpus = 4;
    let big_id = p.run("frag", "mnist", big).unwrap();

    // Small placed immediately; big waits for admission (the §2
    // anecdote in miniature — capacity-blocked work holds in the
    // fair-share queue, not the master's).
    assert!(p.sessions.get(&small_id).unwrap().node.is_some());
    assert_eq!(p.sessions.get(&big_id).unwrap().node, None);
    assert_eq!(p.queued_total(), 1);
    // Stop everything; the big job then gets its node.
    for rec in p.sessions.list() {
        if rec.spec.id != big_id && !rec.state.is_terminal() {
            p.stop(&rec.spec.id).unwrap();
        }
    }
    assert!(p.sessions.get(&big_id).unwrap().node.is_some());
    p.stop(&big_id).unwrap();
}

#[test]
fn events_tell_the_story() {
    use nsml::events::EventKind;
    let Some(p) = platform() else { return };
    let id = p.run("story", "mnist", quick(10, 1)).unwrap();
    p.run_to_completion(5, 10_000).unwrap();
    let events = p.events.for_subject(&id);
    let text: Vec<String> = events.iter().map(|e| e.message()).collect();
    let joined = text.join(" | ");
    assert!(joined.contains("fast-path placed") || joined.contains("placed on"), "{}", joined);
    assert!(joined.contains("container up"), "{}", joined);
    assert!(joined.contains("training"), "{}", joined);
    assert!(joined.contains("done at step"), "{}", joined);
    // The same story is typed, not just strings: placement, state
    // transitions ending in done, metrics, and a checkpoint.
    assert!(
        events.iter().any(|e| matches!(e.kind, EventKind::PlacementDecided { .. })),
        "{}",
        joined
    );
    assert!(
        events
            .iter()
            .any(|e| matches!(&e.kind, EventKind::StateChanged { to, .. } if to == "done")),
        "{}",
        joined
    );
    let has_metric = events.iter().any(|e| matches!(e.kind, EventKind::MetricReported { .. }));
    assert!(has_metric, "{}", joined);
    let has_ckpt = events.iter().any(|e| matches!(e.kind, EventKind::CheckpointSaved { .. }));
    assert!(has_ckpt, "{}", joined);
    // Sequence numbers are a strictly increasing total order.
    assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
}

#[test]
fn derived_views_are_fed_by_the_bus() {
    use nsml::events::EventFilter;
    let Some(p) = platform() else { return };
    // A subscription opened before the run sees everything the derived
    // views consumed.
    let mut done_sub = p
        .events
        .bus()
        .subscribe()
        .with_filter(EventFilter::default().with_kind("state"));
    let id = p.run("derived", "mnist", quick(10, 2)).unwrap();
    p.run_to_completion(5, 10_000).unwrap();

    // Leaderboard was populated by the pump (no direct submit call
    // remains on the completion path) and matches the record.
    let rec = p.sessions.get(&id).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(p.leaderboard.rank_of("mnist", &id), Some(1));
    let board_best = p.leaderboard.best("mnist").unwrap();
    assert_eq!(board_best.value, rec.best_metric.unwrap());
    assert_eq!(board_best.step, rec.steps_done);

    // The monitor's series came off the bus too: one cluster sample and
    // one per-worker sample set per drive round.
    assert!(!p.monitor.is_empty());
    assert!(!p.monitor.latest_workers().is_empty());

    // An independent subscription saw the same done transition the
    // leaderboard consumer acted on.
    let states = done_sub.poll();
    assert!(
        states.iter().any(|e| e.subject == id && e.message().contains("done")),
        "{:?}",
        states.iter().map(|e| e.render()).collect::<Vec<_>>()
    );
    assert_eq!(done_sub.dropped(), 0);
}
