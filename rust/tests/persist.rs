//! Coverage for `api::persist`: state round-trips (sessions with
//! metrics and paused state, checkpoints, leaderboard) and rejection of
//! malformed state files. Pure-logic — no artifacts needed.

use nsml::api::persist::{load, save};
use nsml::leaderboard::{Leaderboard, Submission};
use nsml::session::{SessionRecord, SessionSpec, SessionState, SessionStore};
use nsml::storage::{CheckpointStore, ObjectStore};
use nsml::tenancy::{PriorityClass, TenantQuota, TenantRegistry};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nsml-persist-it-{}-{}", tag, std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn fresh_stores() -> (SessionStore, Leaderboard, CheckpointStore, TenantRegistry) {
    let lb = Leaderboard::new();
    lb.ensure_board("mnist", "accuracy", false);
    (
        SessionStore::new(),
        lb,
        CheckpointStore::new(ObjectStore::memory()),
        TenantRegistry::new(TenantQuota::default()),
    )
}

#[test]
fn populated_paused_session_round_trips() {
    let dir = tmp_dir("paused");
    let (sessions, lb, ckpts, tenants) = fresh_stores();

    // A mid-flight paused session with a full metric history — the
    // §3.3 "pause, edit, resume later" shape that must survive a
    // platform restart.
    let mut spec = SessionSpec::new("lee/mnist/7", "lee", "mnist", "mnist_mlp");
    spec.lr = 0.03;
    spec.seed = 11;
    spec.total_steps = 200;
    spec.checkpoint_every = 25;
    spec.eval_every = 10;
    let mut rec = SessionRecord::new(spec, 1_000);
    rec.state = SessionState::Paused;
    rec.steps_done = 75;
    rec.best_metric = Some(0.81);
    rec.recoveries = 1;
    for step in (10..=70).step_by(10) {
        rec.metrics.log(step, "train_loss", 2.0 / step as f64);
        rec.metrics.log(step, "accuracy", step as f64 / 100.0);
    }
    sessions.insert(rec);

    // Two checkpoints: the periodic one and the pause checkpoint.
    let mut hp = BTreeMap::new();
    hp.insert("lr".to_string(), 0.03);
    hp.insert("seed".to_string(), 11.0);
    ckpts.save("lee/mnist/7", 50, 0.4, &hp, b"params-at-50", 2_000).unwrap();
    ckpts.save("lee/mnist/7", 75, 0.3, &hp, b"params-at-75", 3_000).unwrap();

    lb.submit(
        "mnist",
        Submission {
            session: "lee/mnist/7".into(),
            user: "lee".into(),
            model: "mnist_mlp".into(),
            metric_name: "accuracy".into(),
            value: 0.81,
            step: 70,
            at_ms: 3_000,
        },
    );

    tenants.set_quota(
        "lee",
        TenantQuota {
            max_concurrent: 1,
            max_gpus: 2,
            gpu_second_budget: 45.0,
            weight: 2,
            class: PriorityClass::Low,
        },
    );
    save(&dir, &sessions, &lb, &ckpts, &tenants).unwrap();

    let (sessions2, lb2, ckpts2, tenants2) = fresh_stores();
    load(&dir, &sessions2, &lb2, &ckpts2, &tenants2).unwrap();

    let r = sessions2.get("lee/mnist/7").unwrap();
    assert_eq!(r.state, SessionState::Paused);
    assert_eq!(r.steps_done, 75);
    assert_eq!(r.best_metric, Some(0.81));
    assert_eq!(r.recoveries, 1);
    assert_eq!(r.spec.lr, 0.03);
    assert_eq!(r.spec.seed, 11);
    assert_eq!(r.spec.checkpoint_every, 25);
    assert_eq!(r.metrics.series("train_loss").len(), 7);
    assert_eq!(r.metrics.series("accuracy").len(), 7);

    // Checkpoint index: both snapshots, pause checkpoint latest, with
    // the hyperparameters needed for an lr-edit resume.
    assert_eq!(ckpts2.list("lee/mnist/7").len(), 2);
    let latest = ckpts2.latest("lee/mnist/7").unwrap();
    assert_eq!(latest.step, 75);
    assert_eq!(latest.hparams["lr"], 0.03);
    assert_eq!(latest.hparams["seed"], 11.0);
    assert!(ckpts2.at_step("lee/mnist/7", 50).is_some());

    // Leaderboard survived.
    assert_eq!(lb2.best("mnist").unwrap().value, 0.81);

    // Tenant quota override survived too.
    let q = tenants2.quota_of("lee");
    assert_eq!(q.max_concurrent, 1);
    assert_eq!(q.max_gpus, 2);
    assert_eq!(q.gpu_second_budget, 45.0);
    assert_eq!(q.weight, 2);
    assert_eq!(q.class, PriorityClass::Low);

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn malformed_state_json_is_rejected() {
    let dir = tmp_dir("malformed");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("state.json"), b"{ this is not json ").unwrap();

    let (sessions, lb, ckpts, tenants) = fresh_stores();
    let err = load(&dir, &sessions, &lb, &ckpts, &tenants).unwrap_err();
    assert!(err.to_string().contains("state.json"), "{}", err);
    // Nothing was partially loaded.
    assert!(sessions.is_empty());
    assert!(ckpts.dump().is_empty());

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn truncated_record_surfaces_an_error() {
    let dir = tmp_dir("truncated");
    std::fs::create_dir_all(&dir).unwrap();
    // Valid JSON, but a session record without its spec.
    std::fs::write(
        dir.join("state.json"),
        br#"{"format": 1, "sessions": [{"state": "done", "steps_done": 5}]}"#,
    )
    .unwrap();
    let (sessions, lb, ckpts, tenants) = fresh_stores();
    assert!(load(&dir, &sessions, &lb, &ckpts, &tenants).is_err());

    let _ = std::fs::remove_dir_all(&dir);
}
