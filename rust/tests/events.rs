//! Integration coverage for the event spine: cross-thread follow
//! semantics, filtered cursor pagination, lag accounting, and the
//! `events_since` wire surface — all pure-logic (no artifacts needed).

use nsml::api::{ApiRequest, ApiResponse};
use nsml::events::{EventBus, EventFilter, EventKind, EventLog, Level};
use nsml::util::clock::sim_clock;

fn bus() -> EventBus {
    let (clock, _) = sim_clock();
    EventBus::new(clock)
}

#[test]
fn follower_streams_a_concurrent_publisher() {
    // The `nsml logs -f` shape: a subscriber polls while another thread
    // publishes; every event arrives exactly once, in order.
    let b = bus();
    let mut sub = b.subscribe();
    let publisher = {
        let b = b.clone();
        std::thread::spawn(move || {
            for step in 0..500u64 {
                b.publish(
                    Level::Info,
                    "session",
                    "kim/mnist/1",
                    EventKind::MetricReported { name: "train_loss".into(), step, value: 1.0 },
                );
            }
        })
    };
    let mut seen = Vec::new();
    while seen.len() < 500 {
        seen.extend(sub.poll());
        std::thread::yield_now();
    }
    publisher.join().unwrap();
    assert_eq!(seen.len(), 500);
    assert!(seen.windows(2).all(|w| w[0].seq + 1 == w[1].seq), "gap or reorder in stream");
    assert_eq!(sub.dropped(), 0);
    // Nothing left once the publisher is done.
    assert!(sub.poll().is_empty());
}

#[test]
fn filtered_pagination_never_skips_unscanned_events() {
    let b = bus();
    // Interleave two subjects; page through one with a tiny limit.
    for i in 0..20u64 {
        let subject = if i % 2 == 0 { "a" } else { "b" };
        b.publish(
            Level::Info,
            "session",
            subject,
            EventKind::LogLine { message: format!("{}", i) },
        );
    }
    let filter = EventFilter::default().with_subject("a");
    let mut cursor = 0;
    let mut got = Vec::new();
    loop {
        let batch = b.read_since(cursor, 3, &filter);
        if batch.events.is_empty() {
            break;
        }
        cursor = batch.next;
        got.extend(batch.events);
    }
    assert_eq!(got.len(), 10);
    assert!(got.iter().all(|e| e.subject == "a"));
    let messages: Vec<String> = got.iter().map(|e| e.message()).collect();
    assert_eq!(messages[0], "0");
    assert_eq!(messages[9], "18");
}

#[test]
fn slow_reader_lag_is_surfaced() {
    let (clock, _) = sim_clock();
    let b = EventBus::new(clock).with_capacity(50);
    let mut sub = b.subscribe();
    for i in 0..175u64 {
        b.publish(Level::Info, "x", "", EventKind::LogLine { message: format!("{}", i) });
    }
    let got = sub.poll();
    assert_eq!(got.len(), 50, "only the retained ring is readable");
    assert_eq!(sub.dropped(), 125, "everything aged out unread is counted");
    assert_eq!(got[0].message(), "125");
}

#[test]
fn events_since_round_trips_as_wire_text() {
    // The web route and CLI build this verb from loose args; the whole
    // envelope must survive JSON both ways.
    let req = ApiRequest::EventsSince {
        since: 9,
        kind: Some("steal".into()),
        subject: None,
        limit: 64,
    };
    let text = req.to_json().to_string();
    let back = ApiRequest::from_json(&nsml::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, req);

    let b = bus();
    b.publish(Level::Debug, "executor", "s-1", EventKind::WorkerStolen { thief: 2, victim: 0 });
    let batch = b.read_since(0, 0, &EventFilter::default());
    let resp =
        ApiResponse::Events { events: batch.events, next: batch.next, dropped: 0, overflow: 0 };
    let text = resp.to_json().to_string();
    let back = ApiResponse::from_json(&nsml::util::json::parse(&text).unwrap()).unwrap();
    assert_eq!(back, resp);
}

#[test]
fn legacy_log_shim_shares_the_bus() {
    let (clock, _) = sim_clock();
    let log = EventLog::new(clock);
    let mut sub = log.bus().subscribe();
    // Cloned handles (how subsystems hold the log) publish to one ring.
    let clone = log.clone();
    clone.info("scheduler", "j-1", "queued");
    log.warn("cluster", "node-0", "heartbeat late");
    let got = sub.poll();
    assert_eq!(got.len(), 2);
    assert_eq!(log.len(), 2);
    assert_eq!(log.for_subject("j-1").len(), 1);
    assert_eq!(log.query(Some("cluster"), Level::Warn).len(), 1);
}
