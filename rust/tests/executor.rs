//! Integration: the parallel session execution engine. Sessions train
//! inside the worker pool; control verbs (pause / resume-with-new-lr /
//! stop) and failure isolation work on pool-owned runs, both through
//! the raw [`ExecutorPool`] API and through the platform facade. The
//! work-steal path is covered end-to-end: a skewed submission is stolen
//! by an idle worker, commands follow the re-homed mailbox, and the
//! stolen session's metric history stays contiguous.

use nsml::api::{NsmlPlatform, PlatformConfig, RunOpts};
use nsml::cluster::NodeId;
use nsml::events::EventLog;
use nsml::executor::{ExecutorPool, SessionCommand, SessionOutcome, WorkerCtx};
use nsml::session::{SessionRecord, SessionSpec, SessionState, SessionStore};
use nsml::storage::{CheckpointStore, ObjectStore};
use nsml::util::clock::sim_clock;
use std::path::PathBuf;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn pool_ctx() -> Option<WorkerCtx> {
    let dir = artifacts()?;
    let (clock, _) = sim_clock();
    Some(WorkerCtx {
        artifacts_dir: dir,
        checkpoints: CheckpointStore::new(ObjectStore::memory()),
        sessions: SessionStore::new(),
        events: EventLog::new(clock.clone()).with_echo(false),
        clock,
    })
}

fn platform(workers: usize) -> Option<NsmlPlatform> {
    let mut cfg = PlatformConfig::test_default();
    cfg.artifacts_dir = artifacts()?;
    cfg.workers = workers;
    Some(NsmlPlatform::new(cfg).unwrap())
}

fn spec(id: &str, seed: u64, steps: u64) -> SessionSpec {
    let mut s = SessionSpec::new(id, "pool", "mnist", "mnist_mlp");
    s.total_steps = steps;
    s.eval_every = steps / 2;
    s.checkpoint_every = steps / 2;
    s.seed = seed;
    s
}

#[test]
fn pool_trains_batch_concurrently_to_completion() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pool = ExecutorPool::new(4, ctx.clone());
    for i in 0..8u32 {
        let sp = spec(&format!("pool/mnist/{}", i), i as u64, 24);
        ctx.sessions.insert(SessionRecord::new(sp.clone(), 0));
        pool.submit(sp, false, Some(NodeId(i))).unwrap();
    }
    assert_eq!(pool.len(), 8);
    // Placement maps nodes onto all 4 workers.
    let owners: std::collections::BTreeSet<usize> =
        (0..8).filter_map(|i| pool.owner_of(&format!("pool/mnist/{}", i))).collect();
    assert_eq!(owners.len(), 4, "{:?}", owners);

    let mut done = 0;
    let mut rounds = 0;
    while done < 8 {
        for (id, oc) in pool.step_round(12) {
            match oc {
                SessionOutcome::Completed => done += 1,
                SessionOutcome::Failed(e) => panic!("{}: {}", id, e),
                _ => {}
            }
        }
        rounds += 1;
        assert!(rounds < 100, "batch did not converge");
    }
    assert!(pool.is_empty());
    for i in 0..8 {
        let rec = ctx.sessions.get(&format!("pool/mnist/{}", i)).unwrap();
        assert_eq!(rec.state, SessionState::Done, "{}", rec.spec.id);
        assert_eq!(rec.steps_done, 24);
        assert!(rec.metrics.series("train_loss").len() >= 24);
    }
}

#[test]
fn pause_lr_edit_resume_stop_inside_pool() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pool = ExecutorPool::new(2, ctx.clone());
    let a = spec("pool/mnist/a", 1, 60);
    let b = spec("pool/mnist/b", 2, 60);
    for sp in [&a, &b] {
        ctx.sessions.insert(SessionRecord::new(sp.clone(), 0));
        pool.submit(sp.clone(), false, None).unwrap();
    }
    pool.step_round(20);

    // Pause A mid-training: checkpoint written, state flipped.
    pool.control(&a.id, SessionCommand::Pause).unwrap();
    assert_eq!(ctx.sessions.get(&a.id).unwrap().state, SessionState::Paused);
    assert!(!ctx.checkpoints.list(&a.id).is_empty());
    let paused_at = pool.inspect(&a.id).unwrap().steps_done;

    // A paused session is skipped by rounds; B keeps training.
    let outcomes = pool.step_round(10);
    let oc_a = outcomes.iter().find(|(id, _)| id == &a.id).unwrap();
    assert_eq!(oc_a.1, SessionOutcome::Skipped);
    assert_eq!(pool.inspect(&a.id).unwrap().steps_done, paused_at);
    assert!(pool.inspect(&b.id).unwrap().steps_done > 20);

    // Resume with an edited lr (§3.3 in-training tuning): the command
    // lands on the owning worker; the new lr is live in the run.
    pool.control(&a.id, SessionCommand::Resume { lr: Some(0.007) }).unwrap();
    ctx.sessions.update(&a.id, |r| r.state = SessionState::Running);
    let probe = pool.inspect(&a.id).unwrap();
    assert!((probe.lr - 0.007).abs() < 1e-6, "lr {}", probe.lr);

    // Train past the pause point, then rewind to its checkpoint — the
    // §3.3 "reproduce past state" verb, routed through the mailbox.
    pool.step_round(10);
    assert!(pool.inspect(&a.id).unwrap().steps_done > paused_at);
    pool.control(&a.id, SessionCommand::Rewind(paused_at)).unwrap();
    assert_eq!(pool.inspect(&a.id).unwrap().steps_done, paused_at);
    // Rewinding to a step that was never checkpointed fails cleanly.
    assert!(pool.control(&a.id, SessionCommand::Rewind(paused_at + 1)).is_err());

    // Stop B outright: detached from its worker, A unaffected.
    pool.detach(&b.id);
    assert!(pool.owner_of(&b.id).is_none());
    assert!(pool.inspect(&b.id).is_none());

    // A still trains to completion with the edited lr.
    let mut done = false;
    for _ in 0..20 {
        if pool
            .step_round(20)
            .iter()
            .any(|(id, oc)| id == &a.id && *oc == SessionOutcome::Completed)
        {
            done = true;
            break;
        }
    }
    assert!(done, "paused+resumed session never completed");
    let rec = ctx.sessions.get(&a.id).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 60);
}

#[test]
fn stolen_session_rehomes_commands_and_keeps_history() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pool = ExecutorPool::new(2, ctx.clone());
    // Four sessions all pinned to node 0 — static `node % workers`
    // routing would serialize them on worker 0 while worker 1 idles.
    let ids: Vec<String> = (0..4u64).map(|i| format!("steal/mnist/{}", i)).collect();
    for (i, id) in ids.iter().enumerate() {
        let sp = spec(id, i as u64, 40);
        ctx.sessions.insert(SessionRecord::new(sp.clone(), 0));
        pool.submit(sp, false, Some(NodeId(0))).unwrap();
    }
    // Before the first round everything queues on worker 0's deque.
    let before = pool.stats();
    assert_eq!(before[0].queue_depth, 4, "{:?}", before);
    assert_eq!(before[1].queue_depth, 0, "{:?}", before);

    pool.step_round(10);

    // Work-steal balanced the batch 2/2; worker 1's share was stolen.
    let stats = pool.stats();
    assert_eq!(stats[0].live_sessions, 2, "{:?}", stats);
    assert_eq!(stats[1].live_sessions, 2, "{:?}", stats);
    assert_eq!(stats[0].queue_depth + stats[1].queue_depth, 0, "{:?}", stats);
    assert_eq!(stats[1].steals, 2, "{:?}", stats);
    assert_eq!(pool.total_steals(), 2);

    // Pick a stolen session: its node mapped to worker 0, but worker 1
    // owns it now — the route (mailbox address) was re-homed.
    let stolen = ids.iter().find(|id| pool.owner_of(id) == Some(1)).expect("a stolen session");

    // Pause mid-run: the command must reach the new owner (a stale
    // route to worker 0 would answer "not active").
    pool.control(stolen, SessionCommand::Pause).unwrap();
    assert_eq!(ctx.sessions.get(stolen).unwrap().state, SessionState::Paused);
    assert!(!ctx.checkpoints.list(stolen).is_empty());
    let paused_at = pool.inspect(stolen).unwrap().steps_done;

    // While paused, rounds skip it (other sessions keep training).
    pool.step_round(10);
    assert_eq!(pool.inspect(stolen).unwrap().steps_done, paused_at);

    // lr-edit + resume through the stolen mailbox.
    pool.control(stolen, SessionCommand::Resume { lr: Some(0.004) }).unwrap();
    ctx.sessions.update(stolen, |r| r.state = SessionState::Running);
    let probe = pool.inspect(stolen).unwrap();
    assert!((probe.lr - 0.004).abs() < 1e-6, "lr {}", probe.lr);

    // Everything trains to completion despite the skewed placement.
    let mut done = 0;
    let mut rounds = 0;
    while done < 4 {
        for (id, oc) in pool.step_round(10) {
            match oc {
                SessionOutcome::Completed => done += 1,
                SessionOutcome::Failed(e) => panic!("{}: {}", id, e),
                _ => {}
            }
        }
        rounds += 1;
        assert!(rounds < 100, "skewed batch did not converge");
    }
    assert!(pool.is_empty());

    // The stolen session's metric history is contiguous: exactly one
    // train_loss point per step 1..=40, no gaps or replays across the
    // steal + pause + resume.
    let rec = ctx.sessions.get(stolen).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 40);
    let series = rec.metrics.series("train_loss");
    assert_eq!(series.len(), 40, "history length");
    for (i, (step, _)) in series.iter().enumerate() {
        assert_eq!(*step, (i + 1) as f64, "gap at index {}", i);
    }
}

#[test]
fn failed_materialization_is_terminal_not_stranded() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pool = ExecutorPool::new(1, ctx.clone());
    // Known model, but resume without any checkpoint: submit-time
    // validation passes and materialization fails later.
    let sp = spec("ghost/mnist/0", 0, 20);
    ctx.sessions.insert(SessionRecord::new(sp.clone(), 0));
    pool.submit(sp, true, None).unwrap();
    assert_eq!(pool.len(), 1);
    // An id-addressed command forces materialization; the failure is
    // terminal (record Failed, route gone), never a silent strand.
    let err = pool.control("ghost/mnist/0", SessionCommand::SetLr(0.1)).unwrap_err();
    assert!(err.to_string().contains("checkpoint"), "{}", err);
    assert_eq!(ctx.sessions.get("ghost/mnist/0").unwrap().state, SessionState::Failed);
    assert!(pool.is_empty());
}

#[test]
fn static_routing_pool_never_steals() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    // The bench baseline: work_steal off keeps the skewed batch pinned.
    let pool = ExecutorPool::with_stealing(2, ctx.clone(), false);
    assert!(!pool.stealing());
    for i in 0..3u64 {
        let sp = spec(&format!("static/mnist/{}", i), i, 20);
        ctx.sessions.insert(SessionRecord::new(sp.clone(), 0));
        pool.submit(sp, false, Some(NodeId(0))).unwrap();
    }
    let mut done = 0;
    for _ in 0..50 {
        done += pool
            .step_round(10)
            .iter()
            .filter(|(_, oc)| *oc == SessionOutcome::Completed)
            .count();
        if done == 3 {
            break;
        }
    }
    assert_eq!(done, 3);
    let stats = pool.stats();
    assert_eq!(stats[0].steals + stats[1].steals, 0, "{:?}", stats);
    assert!(stats[1].live_sessions == 0 && stats[1].queue_depth == 0, "{:?}", stats);
}

#[test]
fn bad_spec_fails_spawn_without_poisoning_pool() {
    let Some(ctx) = pool_ctx() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let pool = ExecutorPool::new(2, ctx.clone());
    // Unknown model: the worker rejects the spawn; the pool stays usable.
    let mut bad = spec("pool/bad/1", 0, 10);
    bad.model = "no-such-model".into();
    assert!(pool.submit(bad, false, None).is_err());
    assert!(pool.is_empty());

    let good = spec("pool/good/1", 3, 10);
    ctx.sessions.insert(SessionRecord::new(good.clone(), 0));
    pool.submit(good.clone(), false, None).unwrap();
    let mut completed = false;
    for _ in 0..10 {
        if pool
            .step_round(10)
            .iter()
            .any(|(id, oc)| id == &good.id && *oc == SessionOutcome::Completed)
        {
            completed = true;
            break;
        }
    }
    assert!(completed);
    assert_eq!(ctx.sessions.get(&good.id).unwrap().state, SessionState::Done);
}

#[test]
fn facade_session_control_rides_the_pool() {
    let Some(p) = platform(4) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    assert_eq!(p.executor().worker_count(), 4);
    let opts = RunOpts { total_steps: 60, eval_every: 20, checkpoint_every: 20, ..Default::default() };
    let a = p.run("kim", "mnist", opts.clone()).unwrap();
    let b = p.run("kim", "mnist", RunOpts { seed: 1, ..opts }).unwrap();
    p.drive(20).unwrap();

    // Pause + resume with a new lr through the facade.
    p.pause(&a).unwrap();
    assert_eq!(p.sessions.get(&a).unwrap().state, SessionState::Paused);
    p.resume(&a, Some(0.02)).unwrap();
    assert_eq!(p.sessions.get(&a).unwrap().state, SessionState::Running);
    assert!((p.executor().inspect(&a).unwrap().lr - 0.02).abs() < 1e-6);

    // Stop B mid-run; A still completes.
    p.stop(&b).unwrap();
    assert_eq!(p.sessions.get(&b).unwrap().state, SessionState::Stopped);
    p.run_to_completion(20, 1_000).unwrap();
    let rec = p.sessions.get(&a).unwrap();
    assert_eq!(rec.state, SessionState::Done);
    assert_eq!(rec.steps_done, 60);
    // Pausing a terminal session is a failed precondition.
    assert!(p.pause(&a).is_err());
}

#[test]
fn eight_sessions_complete_across_four_workers_via_facade() {
    let Some(p) = platform(4) else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut ids = Vec::new();
    for i in 0..8 {
        let opts = RunOpts {
            total_steps: 16,
            eval_every: 8,
            checkpoint_every: 8,
            seed: i,
            ..Default::default()
        };
        ids.push(p.run("batch", "mnist", opts).unwrap());
    }
    p.run_to_completion(8, 10_000).unwrap();
    for id in &ids {
        assert_eq!(p.sessions.get(id).unwrap().state, SessionState::Done, "{}", id);
    }
    // All resources released once the pool drained.
    assert!(p.executor().is_empty());
    assert!(p.containers.running().is_empty());
    let (total, free) = p.cluster.gpu_totals();
    assert_eq!(total, free);
}
